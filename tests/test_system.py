"""End-to-end behaviour tests for the framework."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.graph import (Graph, add, convolution, input_data, matmul,
                              max_pool, weight, flatten)
from repro.data import DataPipeline, synthetic_batch
from repro.train import TrainConfig, init_train_state, make_train_step


def test_training_reduces_loss():
    """A few steps of real training on a tiny model reduce the loss."""
    cfg = get_smoke_config("tinyllama_1_1b")
    params, opt, _, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    tc = TrainConfig(lr=3e-3, warmup=2, total_steps=50)
    step = jax.jit(make_train_step(cfg, tc))
    rng = np.random.default_rng(0)
    # overfit one repeated batch — loss must drop markedly
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, 4, 32, rng).items()}
    losses = []
    for i in range(12):
        params, opt, metrics = step(params, opt, batch,
                                    jnp.asarray(i, jnp.int32))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatched_step_matches_full_batch_loss():
    cfg = get_smoke_config("phi3_mini_3_8b")
    params, opt, _, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, 8, 16, rng).items()}
    full = make_train_step(cfg, TrainConfig(n_microbatches=1))
    micro = make_train_step(cfg, TrainConfig(n_microbatches=4))
    _, _, m1 = jax.jit(full)(params, opt, batch, jnp.asarray(0))
    _, _, m2 = jax.jit(micro)(params, opt, batch, jnp.asarray(0))
    assert abs(float(m1["nll"]) - float(m2["nll"])) < 0.05


def test_data_pipeline_prefetch():
    cfg = get_smoke_config("tinyllama_1_1b")
    pipe = DataPipeline(cfg, batch=2, seq=16, n_workers=2, prefetch=2)
    try:
        seen = [next(pipe) for _ in range(4)]
        assert all(b["tokens"].shape == (2, 16) for b in seen)
        assert all((b["tokens"] >= 0).all() and
                   (b["tokens"] < cfg.vocab).all() for b in seen)
    finally:
        pipe.stop()


def test_graph_serialize_execute_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    with Graph(name="lenet-ish", backend="mxu") as g:
        x = input_data("input", rng.standard_normal((1, 8, 8, 1)))
        w0 = weight("w0", rng.standard_normal((3, 3, 1, 4)) * 0.3)
        h = convolution("conv0", x, w0, stride=1, padding="same",
                        activation="relu")
        h = max_pool("pool", h, 2)
        h = flatten("flat", h)
        wf = weight("wf", rng.standard_normal((4 * 4 * 4, 10)) * 0.1)
        matmul("fc", h, wf)
    path = tmp_path / "net"
    g.write_graph(str(path))
    g2 = Graph.read_graph(str(path))
    feed = {"input": rng.standard_normal((1, 8, 8, 1)).astype(np.float32)}
    o1 = g.execute(feed)
    o2 = g2.execute(feed)
    np.testing.assert_allclose(o1["fc"], o2["fc"], rtol=1e-5)
    assert o1["fc"].shape == (1, 10)


def test_graph_fusion_preserves_semantics():
    rng = np.random.default_rng(0)
    with Graph(name="f", backend="mxu") as g:
        x = input_data("input", rng.standard_normal((1, 4, 4, 2)))
        w0 = weight("w0", rng.standard_normal((3, 3, 2, 2)) * 0.3)
        h = convolution("conv0", x, w0, stride=1, padding="same")
        from repro.core.graph import relu
        relu("act", h)
    feed = {"input": rng.standard_normal((1, 4, 4, 2)).astype(np.float32)}
    fused = g.execute(feed, fuse=True)
    unfused = g.execute(feed, fuse=False)
    np.testing.assert_allclose(fused["act"], unfused["act"], rtol=1e-6)
    assert g.fusion_plan()  # the pass actually fused something


def test_paper_nets_build_and_run():
    from repro.configs.paper_nets import PAPER_NETS
    from repro.apps.paper_graphs import build_paper_graph
    rng = np.random.default_rng(0)
    for name in ("minerva", "lenet5", "cnn10"):
        net = PAPER_NETS[name]
        g = build_paper_graph(net, batch=1)
        feed = {"input": rng.standard_normal(
            (1, *net.input_shape)).astype(np.float32)}
        out = g.execute(feed)
        (final,) = out.values()
        assert final.shape[-1] == net.n_classes
        assert np.isfinite(final).all()
