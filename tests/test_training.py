"""The pipeline-parallel training layer: lowering accounting (by hand),
schedule orders, the acceptance invariants (1F1B <= GPipe on every swept
config, measured bubble == (p-1)/(m+p-1) on homogeneous stages, 1-stage
1-microbatch bit-identity with the flat chain, determinism), stage
pinning / link contention, and the error paths.
"""
import dataclasses
import math

import pytest

from repro.core.config import ModelConfig
from repro.sim import engine, ir
from repro.sim.hw import Device, Link, SoCTopology
from repro.sim.ir import (OPTIMIZER_FLOPS_PER_PARAM, from_training_step,
                          partition_stages)
from repro.sim.sweep import as_training_records, training_sweep
from repro.sim.training import (SCHEDULES, bubble_bound, schedule_order,
                                simulate_training)

# 16 layers: divisible by every stage count in the sweeps below, so the
# homogeneous-stage premises of the acceptance invariants hold exactly
TOY = ModelConfig(name="toy16", family="dense", n_layers=16, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                  head_dim=16)


# ---------------------------------------------------------------------------
# from_training_step accounting (hand-computed)


def test_from_training_step_accounting():
    """Every term of the fwd/bwd/reduce/update chain vs the documented
    formulas."""
    bpp, bpa, obp = 2.0, 2.0, 12.0
    seq, batch, dp = 128, 4, 4
    prog = from_training_step(TOY, seq_len=seq, batch=batch,
                              bytes_per_param=bpp, bytes_per_act=bpa,
                              optimizer_bytes_per_param=obp,
                              dp_degree=dp)
    assert [op.name for op in prog.ops] == \
        ["train/fwd", "train/bwd", "train/reduce", "train/update"]
    fwd, bwd, red, upd = prog.ops
    assert bwd.deps == ("train/fwd",)
    assert red.deps == ("train/bwd",)
    assert upd.deps == ("train/reduce",)

    n_active = float(TOY.active_param_count())
    n_full = float(TOY.param_count())
    kv_dim = TOY.n_kv_heads * TOY.resolved_head_dim
    tokens = float(batch * seq)
    attn = 4.0 * TOY.n_layers * kv_dim * (seq * (seq - 1) // 2) * batch
    fwd_flops = 2.0 * n_active * tokens + attn
    act_bytes = TOY.n_layers * TOY.d_model * tokens * bpa
    weight_bytes = n_active * bpp
    grad_bytes = n_active * bpp

    assert fwd.flops == fwd_flops and fwd.dot_flops == fwd_flops
    assert fwd.bytes_in == weight_bytes
    assert fwd.bytes_out == act_bytes                  # stored activations
    # backward: 2x forward flops, weights re-streamed + activations re-read
    assert bwd.flops == 2.0 * fwd_flops
    assert bwd.bytes_in == weight_bytes + act_bytes
    assert bwd.bytes_out == grad_bytes
    # DP all-reduce: operand-sum metric + ring wire bytes
    assert red.collective_bytes == grad_bytes
    assert red.wire_bytes == 2.0 * (dp - 1) / dp * grad_bytes
    # optimizer: full (not active) params, state in and out
    assert upd.flops == OPTIMIZER_FLOPS_PER_PARAM * n_full
    assert upd.bytes_in == grad_bytes + n_full * obp
    assert upd.bytes_out == n_full * obp + weight_bytes


def test_from_training_step_no_reduce_without_dp():
    prog = from_training_step(TOY, seq_len=64, batch=2)
    assert [op.name for op in prog.ops] == \
        ["train/fwd", "train/bwd", "train/update"]
    assert engine.prepare(prog).is_chain


def test_from_training_step_stage_shares_sum_to_whole():
    """Per-stage shares over a balanced partition recompose the flat
    step (to float accumulation tolerance)."""
    flat = from_training_step(TOY, seq_len=128, batch=4)
    for p in (2, 4, 8):
        stages = [from_training_step(TOY, seq_len=128, batch=4,
                                     stage=s, n_stages=p)
                  for s in range(p)]
        for attr in ("flops", "bytes_in", "bytes_out"):
            assert math.fsum(s.total(attr) for s in stages) == \
                pytest.approx(flat.total(attr), rel=1e-12)
    # uneven split still covers every layer
    assert partition_stages(18, 4) == (5, 5, 4, 4)
    assert sum(partition_stages(18, 4)) == 18


def test_from_training_step_errors():
    with pytest.raises(ValueError, match="stage index required"):
        from_training_step(TOY, n_stages=4)
    with pytest.raises(ValueError, match="out of range"):
        from_training_step(TOY, stage=4, n_stages=4)
    with pytest.raises(ValueError, match="every stage needs"):
        partition_stages(2, 4)
    with pytest.raises(ValueError, match="n_stages"):
        partition_stages(8, 0)


# ---------------------------------------------------------------------------
# schedule orders


def test_schedule_orders_cover_every_microbatch():
    for sched in SCHEDULES:
        for p in (1, 2, 4):
            for m in (1, 3, 8):
                for s in range(p):
                    order = schedule_order(sched, s, p, m)
                    assert sorted(x for k, x in order if k == "F") == \
                        list(range(m))
                    assert sorted(x for k, x in order if k == "B") == \
                        list(range(m))
                    # B(m) never precedes F(m) on its own stage
                    seen_f = set()
                    for k, x in order:
                        if k == "F":
                            seen_f.add(x)
                        else:
                            assert x in seen_f


def test_1f1b_order_is_the_megatron_shape():
    # last stage: strict alternation from the start
    assert schedule_order("1f1b", 1, 2, 4) == \
        [("F", 0), ("B", 0), ("F", 1), ("B", 1),
         ("F", 2), ("B", 2), ("F", 3), ("B", 3)]
    # first stage of a 2-pipe: one warmup forward
    assert schedule_order("1f1b", 0, 2, 4)[:3] == \
        [("F", 0), ("F", 1), ("B", 0)]
    with pytest.raises(ValueError, match="unknown schedule"):
        schedule_order("interleaved", 0, 2, 4)


# ---------------------------------------------------------------------------
# acceptance invariants


# configs for the bit-identity / determinism invariants (host model and
# port contention included — those hold everywhere)
SWEPT_CONFIGS = [
    engine.EngineConfig(interface="ideal"),
    engine.EngineConfig(interface="hbm"),
    engine.EngineConfig(interface="hbm", host_dispatch_s=1e-6),
    engine.EngineConfig(interface="acp", host_dispatch_s=1e-6,
                        host_bw=20e9),
]

# configs for the schedule-dominance sweep: no shared-port contention and
# no serial host dispatch.  Those are GLOBALLY-ordered shared resources,
# and 1F1B's steady state keeps both pipeline directions in flight at
# once — roughly doubling its concurrent demand on them versus GPipe's
# phase-separated flush — so contention can genuinely invert the textbook
# ordering (recorded as the headline of benchmarks/bench_training.py).
# On an uncontended homogeneous pipe the dominance is exact.
DOMINANCE_CONFIGS = [
    engine.EngineConfig(interface="ideal"),
    engine.EngineConfig(interface="hbm"),
    engine.EngineConfig(interface="dma"),
    engine.EngineConfig(interface="acp"),
]


@pytest.mark.parametrize("config", DOMINANCE_CONFIGS)
def test_1f1b_never_slower_than_gpipe(config):
    """Acceptance: on every swept (homogeneous-stage, uncontended)
    config, 1F1B step time <= GPipe step time — to 1 ulp, since on many
    cells the two schedules are the same float sum in a different
    order."""
    for p in (1, 2, 4, 8):
        for m in (1, 2, 8):
            g = simulate_training(TOY, n_stages=p, n_microbatches=m,
                                  schedule="gpipe", seq_len=64,
                                  global_batch=8, config=config)
            o = simulate_training(TOY, n_stages=p, n_microbatches=m,
                                  schedule="1f1b", seq_len=64,
                                  global_batch=8, config=config)
            assert o.step_time_s <= g.step_time_s * (1 + 1e-12), \
                (p, m, config.interface)


def test_bubble_matches_analytic_bound_on_homogeneous_stages():
    """Acceptance: with an ideal interface (free transfers) and equal
    stages, the measured bubble fraction IS (p-1)/(m+p-1)."""
    cfg = engine.EngineConfig(interface="ideal")
    for sched in SCHEDULES:
        for p in (2, 4, 8):
            for m in (1, 2, 4, 8):
                r = simulate_training(TOY, n_stages=p, n_microbatches=m,
                                      schedule=sched, seq_len=64,
                                      global_batch=8, config=cfg)
                assert r.bubble_fraction == \
                    pytest.approx(bubble_bound(p, m), rel=1e-9), \
                    (sched, p, m)
                assert r.bubble_bound == bubble_bound(p, m)


def test_uneven_stages_exceed_the_homogeneous_bound():
    """18 layers over 4 stages (5,5,4,4) is not homogeneous: the slowest
    stage paces the pipe, so the measured bubble exceeds the bound."""
    cfg18 = dataclasses.replace(TOY, n_layers=18)
    r = simulate_training(cfg18, n_stages=4, n_microbatches=8,
                          schedule="gpipe", seq_len=64, global_batch=8,
                          config=engine.EngineConfig(interface="ideal"))
    assert r.bubble_fraction > r.bubble_bound + 1e-3


def test_single_stage_single_microbatch_is_the_flat_chain_bitwise():
    """Acceptance: a 1-stage 1-microbatch simulated step is the flat
    ``from_training_step`` chain through ``engine.run``, bit for bit
    (timings, breakdown, roofline, energy; events modulo names)."""
    for config in SWEPT_CONFIGS:
        for dp in (1, 4):
            flat = from_training_step(TOY, seq_len=128, batch=8,
                                      dp_degree=dp)
            a = engine.run(flat, config)
            r = simulate_training(TOY, n_stages=1, n_microbatches=1,
                                  seq_len=128, global_batch=8,
                                  dp_degree=dp, config=config)
            assert engine.prepare(r.program).is_chain
            assert r.step_time_s == a.makespan
            assert r.engine.breakdown == a.breakdown
            assert r.engine.roofline == a.roofline
            assert r.engine.energy == a.energy
            assert [(e.start, e.duration, e.kind, e.worker)
                    for e in r.engine.timeline.events] == \
                [(e.start, e.duration, e.kind, e.worker)
                 for e in a.timeline.events]


def test_training_determinism_bit_identical():
    """Acceptance: two identical runs produce bit-identical results."""
    cfg = engine.EngineConfig(interface="hbm", hbm_ports=2,
                              host_dispatch_s=1e-6)
    for sched in SCHEDULES:
        a = simulate_training(TOY, n_stages=4, n_microbatches=8,
                              schedule=sched, seq_len=64, global_batch=8,
                              config=cfg)
        b = simulate_training(TOY, n_stages=4, n_microbatches=8,
                              schedule=sched, seq_len=64, global_batch=8,
                              config=cfg)
        assert a.step_time_s == b.step_time_s
        assert a.engine.timeline.events == b.engine.timeline.events
        assert a.engine.energy == b.engine.energy
        assert a.per_stage_utilization == b.per_stage_utilization
        assert a.bubble_fraction == b.bubble_fraction


# ---------------------------------------------------------------------------
# stage pinning, transfers, topologies


def test_stages_pin_to_distinct_devices():
    r = simulate_training(TOY, n_stages=4, n_microbatches=2, seq_len=64,
                          global_batch=8)
    for e in r.engine.timeline.events:
        if e.kind == "compute" and e.name[0] in "FBU":
            s = int(e.name.split("/s")[1].split("/")[0])
            assert e.worker == f"stage{s}"
    assert set(r.per_stage_utilization) == {f"stage{s}" for s in range(4)}
    assert all(0.0 < u <= 1.0 for u in r.per_stage_utilization.values())


def test_interstage_transfers_are_real_and_contend():
    """Boundary tensors appear as transfer events on the receiving stage,
    and a 1-port shared link makes the step slower than an uncontended
    one."""
    base = dict(interface="hbm", overlap_transfers=False)
    free = simulate_training(TOY, n_stages=4, n_microbatches=4, seq_len=64,
                             global_batch=8,
                             config=engine.EngineConfig(**base))
    names = {e.name for e in free.engine.timeline.events}
    assert "xF/s1/m0:xfer" in names
    assert "xB/s0/m0:xfer" in names
    tight = simulate_training(TOY, n_stages=4, n_microbatches=4, seq_len=64,
                              global_batch=8,
                              config=engine.EngineConfig(hbm_ports=0.5,
                                                         **base))
    assert tight.step_time_s > free.step_time_s


def test_custom_topology_maps_stages_and_heterogeneity_shows():
    """A provided topology's accel devices become the stages in order;
    a half-speed stage inflates the measured bubble past the bound."""
    soc = SoCTopology(
        devices=(Device("fast0"), Device("slow", peak_flops=1e11),
                 Device("fast1"), Device("fast2")),
        links=(Link("hbm"),), name="hetero")
    cfg = engine.EngineConfig(interface="ideal", peak_flops=2e11,
                              topology=soc)
    r = simulate_training(TOY, n_stages=4, n_microbatches=8, seq_len=64,
                          global_batch=8, config=cfg)
    assert set(r.per_stage_utilization) == {"fast0", "slow", "fast1",
                                            "fast2"}
    assert r.bubble_fraction > r.bubble_bound + 1e-3
    # the slow stage is the busiest
    assert max(r.per_stage_utilization,
               key=r.per_stage_utilization.get) == "slow"


def test_simulate_training_errors():
    with pytest.raises(ValueError, match="not divisible"):
        simulate_training(TOY, n_stages=2, n_microbatches=3,
                          global_batch=8)
    with pytest.raises(ValueError, match="unknown schedule"):
        simulate_training(TOY, schedule="zb-h1")
    with pytest.raises(ValueError, match="n_microbatches"):
        simulate_training(TOY, n_microbatches=0)
    soc = SoCTopology(devices=(Device("a0"), Device("a1")))
    with pytest.raises(ValueError, match="stage-capable"):
        simulate_training(TOY, n_stages=4, n_microbatches=4,
                          global_batch=8,
                          config=engine.EngineConfig(topology=soc))


# ---------------------------------------------------------------------------
# the sweep grid and the launcher dry-run


def test_training_sweep_grid_and_records():
    results = training_sweep(TOY, n_stages_grid=(1, 2), seq_len=64,
                             n_microbatches_grid=(1, 4))
    assert len(results) == 8          # 2 stages x 2 microbatches x 2 scheds
    rows = as_training_records(results)
    assert [r["n_stages"] for r in rows] == [1, 1, 1, 1, 2, 2, 2, 2]
    assert {r["schedule"] for r in rows} == {"gpipe", "1f1b"}
    # every cell simulated the same token count (LCM global batch)
    assert len({r["global_batch"] for r in rows}) == 1
    for row in rows:
        assert set(row) >= {"model", "schedule", "n_stages",
                            "n_microbatches", "step_time_s", "tokens_per_s",
                            "bubble_fraction", "bubble_bound",
                            "stage_util_mean", "total_j"}
        assert row["step_time_s"] > 0.0
        assert 0.0 <= row["bubble_fraction"] < 1.0


def test_training_sweep_rejects_indivisible_global_batch():
    with pytest.raises(ValueError, match="not divisible"):
        training_sweep(TOY, n_stages_grid=(1,), n_microbatches_grid=(3,),
                       global_batch=8)


def test_launcher_dry_run_uses_the_simulator():
    from repro.launch.train import dry_run
    lines = []
    out = dry_run("gemma_2b", "train_4k", n_stages=2, n_microbatches=4,
                  smoke=True, emit=lines.append)
    assert [r.schedule for r in out] == ["gpipe", "1f1b"]
    assert out[1].step_time_s <= out[0].step_time_s * (1 + 1e-12)
    assert len(lines) == 2 and all("bubble=" in ln for ln in lines)


def test_stage_layer_slices_match_partition():
    from repro.dist.pipeline import stage_layer_slices
    assert stage_layer_slices(18, 4) == [(0, 5), (5, 10), (10, 14),
                                         (14, 18)]
    for n, p in ((16, 4), (22, 8), (7, 7)):
        slices = stage_layer_slices(n, p)
        assert [hi - lo for lo, hi in slices] == list(partition_stages(n, p))
        assert slices[0][0] == 0 and slices[-1][1] == n
        assert all(a[1] == b[0] for a, b in zip(slices, slices[1:]))
