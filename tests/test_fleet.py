"""Fleet-scale serving replay: the memoized step-cost table, the lite
aggregate-counter scheduler, the multi-replica router/autoscaler layer,
and the streaming trace I/O — all held to the PR-3 co-simulation's
arithmetic bit for bit."""
import math

import pytest

from repro.core.config import ModelConfig
from repro.serve.policy import (QueueDepthAutoscaler, get_policy,
                                get_router)
from repro.sim import engine, ir
from repro.sim.engine import EngineConfig
from repro.sim.report import latency_stats, latency_stats_array
from repro.sim.serving import (Request, StepCostTable, TraceArrays,
                               TRACE_GENERATORS, as_fleet_records,
                               as_serving_records, bursty_trace,
                               diurnal_trace, iter_trace, load_trace,
                               poisson_trace, replay_serving, save_trace,
                               simulate_fleet, simulate_serving)

TOY = ModelConfig(name="toy", family="dense", n_layers=2, d_model=8,
                  n_heads=2, n_kv_heads=2, d_ff=16, vocab=32, head_dim=4)

POLICY_NAMES = ("static", "dynamic", "continuous")
CONFIGS = [
    EngineConfig(),
    EngineConfig(interface="hbm", hbm_ports=0.5, host_dispatch_s=5e-6,
                 datapath_scale=1.5),
    EngineConfig(interface="dma", host_threads=2),
]


def _policies(max_batch=4):
    return [get_policy(n, max_batch=max_batch) for n in POLICY_NAMES]


# ---------------------------------------------------------------------------
# the memo: StepCostTable == engine.chain_op_costs, bit for bit


@pytest.mark.parametrize("config", CONFIGS)
def test_step_cost_table_matches_chain_op_costs(config):
    """Every (prefill tuple, decode composition) the table prices must
    reproduce the engine's per-op chain terms exactly — including on
    interfaces (dma) that take the un-fast fallback path."""
    import random
    rng = random.Random(11)
    table = StepCostTable(TOY, config)
    for trial in range(50):
        pf = tuple(rng.randint(1, 40)
                   for _ in range(rng.randint(0, 4)))
        dpos = tuple(rng.randint(1, 200)
                     for _ in range(rng.randint(0, 6)))
        if not pf and not dpos:
            continue
        prog = ir.from_serving_step(TOY, step=trial, prefill_lens=pf,
                                    decode_positions=dpos)
        exact = [engine.chain_op_costs(op, config) for op in prog.ops]
        memo = table.step_entries(pf, len(dpos), sum(dpos))
        assert len(memo) == len(exact)
        for entry, terms in zip(memo, exact):
            assert entry[:4] == terms            # (host, xfer, comp, coll)
        assert table.step_entries(pf, len(dpos), sum(dpos)) == memo
    assert table.hits > 0 and 0.0 < table.hit_rate < 1.0


def test_step_cost_table_signature_sufficiency():
    """The decode entry depends on positions only through (count, sum) —
    the exact claim ``ir.serving_step_signature`` documents."""
    config = EngineConfig()
    table = StepCostTable(TOY, config)
    a = table.step_entries((), 3, 60)
    for dpos in ((20, 20, 20), (1, 1, 58), (50, 9, 1)):
        prog = ir.from_serving_step(TOY, step=0, prefill_lens=(),
                                    decode_positions=dpos)
        exact = [engine.chain_op_costs(op, config) for op in prog.ops]
        assert [e[:4] for e in a] == exact


def test_step_cost_table_mismatch_rejected():
    table = StepCostTable(TOY, EngineConfig())
    other = EngineConfig(hbm_ports=2.0)
    assert not table.matches(TOY, other, 2.0)
    with pytest.raises(ValueError, match="different"):
        replay_serving(TOY, poisson_trace(4, 10.0), _policies()[0],
                       other, table=table)


def test_signature_helpers_round_trip():
    sig = ir.serving_step_signature((3, 5), (7, 9, 11))
    assert sig == ((3, 5), 3, 27)
    pos = ir.positions_for_signature(3, 27)
    assert len(pos) == 3 and sum(pos) == 27 and min(pos) >= 1
    assert ir.positions_for_signature(0, 0) == ()


# ---------------------------------------------------------------------------
# the lite replay: bit-identical to the full co-simulation


@pytest.mark.parametrize("config", CONFIGS[:2])
@pytest.mark.parametrize("kind", ["poisson", "bursty"])
def test_replay_bit_identical_to_simulate(kind, config):
    """replay_serving == simulate_serving on wall/busy clocks, step
    records, per-request times, and every stats() field — all policies,
    both trace shapes."""
    gen = poisson_trace if kind == "poisson" else bursty_trace
    trace = gen(80, 60.0, seed=4)
    for policy in _policies():
        a = simulate_serving(TOY, trace, policy, config)
        b = replay_serving(TOY, trace, policy, config,
                           record_steps=True)
        assert b.busy_s == a.busy_s
        assert b.makespan_s == a.makespan_s
        assert b.n_steps == len(a.steps)
        assert b.steps == a.steps
        am = {m.rid: (m.first_token_s, m.finish_s) for m in a.requests}
        bm = {m.rid: (m.first_token_s, m.finish_s) for m in b.requests}
        assert am == bm
        assert b.stats() == a.stats()


def test_simulate_serving_memoize_toggle_identical():
    """memoize=True changes the cost of simulate_serving, not a single
    bit of its result."""
    trace = bursty_trace(48, 90.0, seed=2)
    for policy in _policies():
        on = simulate_serving(TOY, trace, policy, memoize=True)
        off = simulate_serving(TOY, trace, policy, memoize=False)
        assert on.busy_s == off.busy_s
        assert on.makespan_s == off.makespan_s
        assert on.stats() == off.stats()


def test_replay_energy_matches_engine():
    """The replay's energy roll-up equals the engine's on the same trace
    (same terms, possibly different float summation order)."""
    trace = poisson_trace(48, 60.0, seed=2)
    policy = get_policy("continuous", max_batch=4)
    a = simulate_serving(TOY, trace, policy)
    b = replay_serving(TOY, trace, policy)
    ea, eb = a.engine.energy, b.energy()
    assert set(eb) == set(ea)
    for k in ea:
        assert eb[k] == pytest.approx(ea[k], rel=1e-9, abs=1e-18)


def test_replay_accepts_sorted_stream_and_rejects_unsorted():
    trace = poisson_trace(24, 40.0, seed=6)
    policy = get_policy("continuous", max_batch=4)
    a = replay_serving(TOY, trace, policy)
    b = replay_serving(TOY, iter(trace), policy)
    assert a.makespan_s == b.makespan_s
    bad = [Request(0, 1.0, 4, 2), Request(1, 0.5, 4, 2)]
    with pytest.raises(ValueError, match="sorted"):
        replay_serving(TOY, iter(bad), policy)
    with pytest.raises(ValueError, match="duplicate rid"):
        replay_serving(TOY, [Request(3, 0.0, 4, 2),
                             Request(3, 0.5, 4, 2)], policy)


# ---------------------------------------------------------------------------
# the fleet: routers conserve requests, N=1 degenerates to replay


def test_fleet_single_replica_is_replay():
    trace = poisson_trace(60, 80.0, seed=9)
    for policy in _policies():
        b = replay_serving(TOY, trace, policy)
        f = simulate_fleet(TOY, trace, policy, n_replicas=1)
        assert f.makespan_s == b.makespan_s
        assert f.busy_s == b.busy_s
        assert list(f.first_token_s) == list(b.first_token_s)
        assert list(f.finish_s) == list(b.finish_s)


@pytest.mark.parametrize("router", ["round_robin", "least_outstanding",
                                    "session_affinity"])
def test_fleet_router_conserves_requests(router):
    """Every request is routed to exactly one replica and served exactly
    once: finish times all finite, per-replica rid sets partition the
    trace."""
    import numpy as np
    trace = bursty_trace(200, 150.0, seed=1)
    policy = get_policy("continuous", max_batch=4)
    f = simulate_fleet(TOY, trace, policy, n_replicas=3, router=router)
    assert np.isfinite(np.asarray(f.finish_s)).all()
    assert np.isfinite(np.asarray(f.first_token_s)).all()
    seen = sorted(int(r) for rep in f.replicas for r in rep.rid)
    assert seen == sorted(r.rid for r in trace)
    ro = np.asarray(f.replica_of)
    for rep in f.replicas:
        idx = rep.meta["replica"]
        assert int(np.count_nonzero(ro == idx)) == len(rep.rid)
    # per-request ordering invariants hold globally
    assert (np.asarray(f.first_token_s)
            >= np.asarray(f.arrival_s)).all()
    assert (np.asarray(f.finish_s)
            >= np.asarray(f.first_token_s)).all()


def test_fleet_round_robin_assignment():
    trace = poisson_trace(12, 50.0, seed=0)
    policy = get_policy("continuous", max_batch=4)
    f = simulate_fleet(TOY, trace, policy, n_replicas=3,
                       router="round_robin")
    assert list(f.replica_of) == [i % 3 for i in range(12)]


def test_fleet_session_affinity_is_sticky():
    """The affinity hash depends only on rid, so a session's requests
    always land on the same replica regardless of arrival order."""
    router = get_router("session_affinity")
    a = router.route(42, 0, ()) % 4
    assert all(router.route(42, s, ()) % 4 == a for s in range(5))
    assert len({router.route(rid, 0, ()) % 4
                for rid in range(64)}) > 1       # and it does spread


def test_fleet_stats_and_records():
    trace = diurnal_trace(300, 400.0, seed=7)
    policy = get_policy("continuous", max_batch=4)
    f = simulate_fleet(TOY, trace, policy, n_replicas=2)
    s = f.stats()
    assert 0.0 <= s["slo_attainment"] <= 1.0
    assert s["n_requests"] == 300 and s["n_replicas"] == 2
    assert s["cost_per_token_j"] > 0.0
    assert math.isfinite(s["makespan_s"]) and s["makespan_s"] > 0.0
    # generous SLO -> everyone attains; impossible SLO -> no one does
    assert f.slo_attainment(ttft_slo_s=1e9, tpot_slo_s=1e9) == 1.0
    assert f.slo_attainment(ttft_slo_s=-1.0, tpot_slo_s=1e-12) == 0.0
    recs = as_fleet_records([f])
    assert len(recs) == 1 and recs[0]["router"] == "round_robin"
    per = as_fleet_records([f], per_replica=True)
    assert len(per) == 2
    assert all("trace_kind" in r and "rate_rps" in r for r in per)


def test_autoscaler_bounds_cooldown_and_events():
    scaler = QueueDepthAutoscaler(min_replicas=1, max_replicas=3,
                                  scale_up_depth=4.0,
                                  scale_down_depth=0.5, cooldown_s=0.1)
    # pure decision logic
    assert scaler.decide(1, 10.0, 1.0, 0.99) == 0      # inside cooldown
    assert scaler.decide(1, 10.0, 1.0, 0.0) == 1
    assert scaler.decide(3, 10.0, 1.0, 0.0) == 0       # at max
    assert scaler.decide(2, 0.1, 1.0, 0.0) == -1
    assert scaler.decide(1, 0.1, 1.0, 0.0) == 0        # at min
    # end to end: a bursty overload must trigger scale-ups, stay in
    # bounds, and still serve every request exactly once
    import numpy as np
    trace = bursty_trace(400, 300.0, seed=8)
    policy = get_policy("continuous", max_batch=2)
    f = simulate_fleet(TOY, trace, policy, n_replicas=1,
                       router="least_outstanding", autoscaler=scaler)
    assert np.isfinite(np.asarray(f.finish_s)).all()
    assert sum(len(r.rid) for r in f.replicas) == 400
    for e in f.scale_events:
        assert 1 <= e.n_replicas <= 3
        assert e.action in ("up", "down")
    ts = [e.t_s for e in f.scale_events]
    assert all(b - a >= scaler.cooldown_s - 1e-12
               for a, b in zip(ts, ts[1:]))


# ---------------------------------------------------------------------------
# traces: diurnal generator, columnar arrays, streaming I/O


def test_diurnal_trace_properties():
    tr = diurnal_trace(64, 100.0, seed=5)
    assert len(tr) == 64
    assert all(isinstance(r, Request) for r in tr)
    assert all(a.arrival_s <= b.arrival_s for a, b in zip(tr, tr[1:]))
    assert all(r.arrival_s >= 0.0 and r.prompt_len >= 1
               and r.output_len >= 1 for r in tr)
    assert tr == diurnal_trace(64, 100.0, seed=5)        # deterministic
    assert tr != diurnal_trace(64, 100.0, seed=6)
    assert TRACE_GENERATORS["diurnal"] is diurnal_trace
    with pytest.raises(ValueError, match="amplitude"):
        diurnal_trace(8, 10.0, amplitude=1.5)


def test_diurnal_arrays_agree_with_list():
    ta = diurnal_trace(50, 200.0, seed=3, arrays=True)
    tl = diurnal_trace(50, 200.0, seed=3)
    assert isinstance(ta, TraceArrays) and len(ta) == 50
    assert list(ta) == tl                        # same Requests, same bits
    policy = get_policy("continuous", max_batch=4)
    a = replay_serving(TOY, ta, policy)
    b = replay_serving(TOY, tl, policy)
    assert a.makespan_s == b.makespan_s and a.busy_s == b.busy_s


def test_diurnal_rate_modulation():
    """The sinusoidal intensity rate*(1 + A*sin(2*pi*t/P)) peaks in the
    first half-period, so at amplitude 0.9 the first half of the day
    holds well over half the requests."""
    import numpy as np
    tr = diurnal_trace(4000, 100.0, period_s=40.0, amplitude=0.9,
                       seed=0, arrays=True)
    t = np.asarray(tr.arrival_s)
    first_half = (t < 20.0).mean()
    assert first_half > 0.65
    # flat amplitude=0 degenerates to an ordinary Poisson process
    flat = diurnal_trace(4000, 100.0, period_s=40.0, amplitude=0.0,
                         seed=0, arrays=True)
    tf = np.asarray(flat.arrival_s)
    assert abs((tf < 20.0).mean() - 0.5) < 0.1


def test_trace_gzip_round_trip_and_lazy_iter(tmp_path):
    trace = diurnal_trace(40, 80.0, seed=1)
    p = tmp_path / "trace.jsonl.gz"
    save_trace(p, trace)
    assert load_trace(p) == trace                # bit-identical floats
    it = iter_trace(p)
    assert next(it) == trace[0]                  # lazy: partial consume OK
    assert list(it) == trace[1:]
    # a generator (no len, no indexing) feeds save_trace and replay
    p2 = tmp_path / "stream.jsonl.gz"
    save_trace(p2, (r for r in trace))
    policy = get_policy("continuous", max_batch=4)
    a = replay_serving(TOY, iter_trace(p2), policy)
    b = replay_serving(TOY, trace, policy)
    assert a.makespan_s == b.makespan_s
    assert a.stats() == b.stats()


def test_as_serving_records_uniform_columns():
    """Every record carries rate_rps/trace_kind — sweep cells filled in,
    ad-hoc runs None — so mixed-provenance tables never KeyError."""
    trace = poisson_trace(16, 40.0, seed=0)
    policy = get_policy("continuous", max_batch=4)
    sim = simulate_serving(TOY, trace, policy)
    rep = replay_serving(TOY, trace, policy)
    recs = as_serving_records([sim, rep])
    keys = set(recs[0])
    for r in recs:
        assert set(r) == keys
        assert "rate_rps" in r and "trace_kind" in r
    # sim's engine makespan == replay's busy clock, bit for bit
    assert recs[0]["engine_makespan_s"] == recs[1]["engine_makespan_s"]


def test_latency_stats_array_matches_scalar():
    import random
    rng = random.Random(3)
    for n in (0, 1, 2, 7, 100):
        xs = [rng.uniform(0.0, 5.0) for _ in range(n)]
        assert latency_stats_array(xs) == latency_stats(xs)


# ---------------------------------------------------------------------------
# hypothesis properties (skipped automatically when hypothesis is absent)


from _hyp import given, settings, st  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(pf=st.lists(st.integers(1, 64), max_size=5),
       dpos=st.lists(st.integers(1, 300), max_size=8))
def test_memo_matches_engine_property(pf, dpos):
    """StepCostTable == chain_op_costs for ANY step composition."""
    if not pf and not dpos:
        return
    config = EngineConfig()
    table = StepCostTable(TOY, config)
    prog = ir.from_serving_step(TOY, step=0, prefill_lens=tuple(pf),
                                decode_positions=tuple(dpos))
    exact = [engine.chain_op_costs(op, config) for op in prog.ops]
    memo = table.step_entries(tuple(pf), len(dpos), sum(dpos))
    assert [e[:4] for e in memo] == exact


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 60), rate=st.floats(1.0, 400.0),
       seed=st.integers(0, 2**16), n_replicas=st.integers(1, 4),
       router=st.sampled_from(["round_robin", "least_outstanding",
                               "session_affinity"]))
def test_fleet_conservation_property(n, rate, seed, n_replicas, router):
    """For ANY trace and fleet shape, the router neither loses nor
    duplicates a request."""
    import numpy as np
    trace = poisson_trace(n, rate, seed=seed)
    policy = get_policy("continuous", max_batch=4)
    f = simulate_fleet(TOY, trace, policy, n_replicas=n_replicas,
                       router=router)
    assert np.isfinite(np.asarray(f.finish_s)).all()
    assert sorted(int(r) for rep in f.replicas for r in rep.rid) \
        == list(range(n))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 48), rate=st.floats(1.0, 300.0),
       seed=st.integers(0, 2**16),
       pname=st.sampled_from(list(POLICY_NAMES)))
def test_replay_identity_property(n, rate, seed, pname):
    """For ANY poisson trace and policy, the lite replay reproduces the
    full co-simulation bit for bit."""
    trace = poisson_trace(n, rate, seed=seed)
    policy = get_policy(pname, max_batch=4)
    a = simulate_serving(TOY, trace, policy)
    b = replay_serving(TOY, trace, policy)
    assert (a.busy_s, a.makespan_s) == (b.busy_s, b.makespan_s)
    assert a.stats() == b.stats()


@settings(max_examples=30, deadline=None)
@given(xs=st.lists(st.floats(0.0, 1e4), max_size=64))
def test_latency_stats_array_property(xs):
    assert latency_stats_array(xs) == latency_stats(xs)
