"""Tiling optimizer: unit + hypothesis property tests on its invariants."""
import math

import pytest
from _hyp import given, settings, st

from repro.core.tensor import TensorSpec
from repro.core.tiling import (MXU_DIM, choose_matmul_tiling, choose_tiling,
                               enumerate_tilings)

dims_st = st.tuples(st.sampled_from([1, 2, 4]),
                    st.sampled_from([4, 8, 16, 64]),
                    st.sampled_from([4, 8, 16, 64]),
                    st.sampled_from([8, 32, 128, 512]))


@given(shape=dims_st, budget=st.sampled_from([1024, 4096, 16384, 65536]))
@settings(max_examples=60, deadline=None)
def test_tiles_fit_budget_and_cover(shape, budget):
    spec = TensorSpec(shape, "NHWC", "float32")
    for c in enumerate_tilings(spec, budget, reduce_dim="C"):
        assert math.prod(c.tile_shape) <= budget
        # tiles cover the tensor
        covered = 1
        for full, t in zip(shape, c.tile_shape):
            assert 1 <= t <= full
            covered *= math.ceil(full / t)
        assert covered == c.n_tiles
        assert c.n_memcpys >= 1
        assert c.contiguous_run >= 1


@given(shape=dims_st, budget=st.sampled_from([4096, 16384]))
@settings(max_examples=40, deadline=None)
def test_chosen_is_pareto_on_host_cost(shape, budget):
    """The chosen tiling is never strictly dominated (worse util AND worse
    host cost) by another candidate."""
    spec = TensorSpec(shape, "NHWC", "float32")
    cands = enumerate_tilings(spec, budget, reduce_dim="C")
    if not cands:
        return
    best = choose_tiling(spec, budget, reduce_dim="C")
    for c in cands:
        assert not (c.utilization > best.utilization + 1e-9
                    and c.host_cost_s < best.host_cost_s - 1e-12)


def test_contiguity_beats_channel_tiling():
    """Paper Fig 6: row-wise tiling beats channel-wise for NHWC tensors."""
    spec = TensorSpec((1, 16, 16, 128), "NHWC", "float32")
    cands = {c.strategy: c for c in enumerate_tilings(spec, 16384,
                                                      reduce_dim="C")}
    assert cands["DimC"].host_cost_s > cands["DimH"].host_cost_s
    # the large-tensor case: DimHW >> cheaper than DimHC
    spec = TensorSpec((1, 64, 64, 512), "NHWC", "float32")
    cands = {c.strategy: c for c in enumerate_tilings(spec, 16384,
                                                      reduce_dim="C")}
    assert cands["DimHC"].host_cost_s > 5 * cands["DimHW"].host_cost_s
    assert cands["DimHW"].n_memcpys == 128        # paper's exact number
    assert cands["DimHW"].contiguous_run == 16384  # 16K-element memcpys


@given(m=st.sampled_from([128, 384, 1024, 4096]),
       n=st.sampled_from([128, 256, 2048]),
       k=st.sampled_from([128, 512, 5632]))
@settings(max_examples=30, deadline=None)
def test_matmul_tiling_mxu_aligned_and_fits(m, n, k):
    t = choose_matmul_tiling(m, n, k)
    assert t.bm <= m and t.bn <= n and t.bk <= k
    ws = (t.bm * t.bk + t.bk * t.bn) * 2 + t.bm * t.bn * 4
    assert ws <= 64 * 1024 * 1024  # half of VMEM
    for b, dim in ((t.bm, m), (t.bn, n), (t.bk, k)):
        if dim >= MXU_DIM:
            assert b % MXU_DIM == 0


def test_infeasible_raises():
    spec = TensorSpec((1, 1, 1, 8), "NHWC", "float32")
    with pytest.raises(ValueError):
        choose_tiling(spec, 0, reduce_dim="C")
