"""The cluster fabric layer: bit-identity with the pre-fabric simulator,
placement math, DP x TP x PP training sanity, and TCO.

The refactor's contract: a ``Fabric`` is pure ADDITION.  A single-tier
fabric attached to a config — or threaded through ``simulate_training`` —
must reproduce every pre-refactor number bit-for-bit (same floats, not
just close), and the dp ring's collective lane time must equal the
pre-refactor ring wire term ``2 (d-1)/d grad_bytes / ici_bw`` exactly.
"""
import dataclasses

import pytest

from repro.core.config import ModelConfig
from repro.sim import engine, ir, training
from repro.sim.engine import EngineConfig
from repro.sim.hw import Fabric, FabricTier, tco_per_step
from repro.sim.sweep import (as_cluster_records, cluster_sweep,
                             placements_for)

TOY = ModelConfig(name="toy16", family="dense", n_layers=16, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                  head_dim=16)

REL = 1e-12


def _rel(a, b):
    return abs(a - b) / max(abs(a), abs(b), 1e-300)


# ---------------------------------------------------------------------------
# fabric data model


def test_fabric_shapes():
    fab = Fabric.cluster(64)
    assert fab.n_accel == 64
    assert fab.describe() == "4ici x 8node x 2inter"
    assert fab.leaves_per_group() == (4, 32, 64)
    assert Fabric.cluster(4).describe() == "4ici"
    assert Fabric.cluster(8).describe() == "4ici x 2node"
    assert Fabric.single_tier(8).n_accel == 8


def test_span_tier_and_lanes():
    fab = Fabric.cluster(64)
    assert fab.span_tier((0, 1, 2, 3)) == 0          # one chip
    assert fab.span_tier((0, 4)) == 1                # two chips, one node
    assert fab.span_tier((0, 32)) == 2               # two nodes
    assert fab.lane((0, 1, 2, 3)) == "ici:0"
    assert fab.lane((4, 5)) == "ici:4"
    assert fab.lane((0, 32)) == "inter:0"
    # same tier, disjoint leading member -> distinct physical links
    assert fab.lane((0, 4)) != fab.lane((8, 12))


def test_placements_cover_the_accelerator_count():
    for n in (8, 64, 512):
        cells = placements_for(n)
        assert cells, n
        assert all(dp * pp * tp == n for dp, pp, tp in cells)
        assert len(set(cells)) == len(cells)
    assert (512 // 64, 8, 8) in placements_for(512)  # all three degrees > 1


def test_tco_monotone():
    base = tco_per_step(8, 0.1, 100.0)
    assert tco_per_step(16, 0.1, 100.0) > base       # more capex
    assert tco_per_step(8, 0.1, 200.0) > base        # more energy
    assert tco_per_step(8, 0.2, 100.0) > base        # longer amortized step
    assert tco_per_step(8, 0.0, 0.0) == 0.0


# ---------------------------------------------------------------------------
# bit-identity with the pre-fabric simulator


@pytest.mark.parametrize("kw", [
    dict(),
    dict(n_stages=2, n_microbatches=4),
    dict(n_stages=4, n_microbatches=8, schedule="gpipe"),
    dict(dp_degree=4),
    dict(n_stages=2, n_microbatches=2, dp_degree=2),
])
def test_single_tier_fabric_training_bit_identical(kw):
    """The frozen training matrix: attaching a default single-tier fabric
    must not move ANY reported float (the fabric only changes behavior
    when a program carries tier ops with non-ici lanes or overrides)."""
    a = training.simulate_training(TOY, global_batch=8, **kw)
    b = training.simulate_training(TOY, global_batch=8,
                                   fabric=Fabric.single_tier(16), **kw)
    if "dp_degree" in kw:
        # dp now lowers through the fabric: same collective lane total as
        # the legacy ring wire accounting (checked below), but the
        # per-hop schedule differs — identity applies to the no-dp cells
        assert b.step_time_s > 0.0
        return
    assert a.step_time_s == b.step_time_s
    assert a.stats() == b.stats()
    assert a.engine.energy["total_j"] == b.engine.energy["total_j"]


@pytest.mark.parametrize("make", [
    lambda: ir.from_decode(TOY, 8),
    lambda: ir.from_serving_step(TOY, prefill_lens=(64, 32),
                                 decode_positions=(10, 20)),
    lambda: ir.from_training_step(TOY, seq_len=128, batch=4),
    lambda: ir.from_training_step(TOY, seq_len=128, batch=4, dp_degree=4),
])
def test_config_fabric_is_invisible_without_tier_ops(make):
    """The frozen serving/decode/training-chain matrix: a fabric on the
    CONFIG changes nothing for legacy programs — chain fast path, event
    loop, energy, roofline all bit-identical."""
    prog = make()
    cfg = EngineConfig()
    cfg_fab = dataclasses.replace(cfg, fabric=Fabric.single_tier(8))
    a = engine.run(prog, cfg)
    b = engine.run(prog, cfg_fab)
    assert a.makespan == b.makespan
    assert a.breakdown == b.breakdown
    assert a.energy["total_j"] == b.energy["total_j"]
    assert a.roofline.step_s == b.roofline.step_s


def test_dp_ring_matches_pre_refactor_wire_term():
    """The new per-hop ring's lane total == the legacy single op's ring
    wire accounting ``2 (d-1)/d grad_bytes / ici_bw`` (rel 1e-12)."""
    cfg = EngineConfig()
    for d in (2, 4, 8):
        r = training.simulate_training(
            TOY, global_batch=8, dp_degree=d,
            fabric=Fabric.single_tier(8))
        legacy = ir.from_training_step(TOY, seq_len=512, batch=8,
                                       dp_degree=d)
        wire = next(op.wire_bytes for op in legacy.ops
                    if op.name == "train/reduce")
        assert _rel(r.stats()["collective_s"], wire / cfg.ici_bw) <= REL


# ---------------------------------------------------------------------------
# DP x TP x PP over the fabric


def test_tp_requires_fabric_and_placement_must_fit():
    with pytest.raises(ValueError):
        training.simulate_training(TOY, global_batch=8, tp_degree=2)
    with pytest.raises(ValueError):
        training.simulate_training(TOY, global_batch=8, dp_degree=4,
                                   tp_degree=4,
                                   fabric=Fabric.single_tier(8))


def test_tp_shrinks_compute_and_adds_collectives():
    fab = Fabric.cluster(8)
    r1 = training.simulate_training(TOY, global_batch=8, fabric=fab)
    r2 = training.simulate_training(TOY, global_batch=8, tp_degree=4,
                                    fabric=fab)
    assert r2.stats()["collective_s"] > 0.0
    assert r1.stats()["collective_s"] == 0.0
    # per-rank flops drop 4x; the program records that in the fwd op
    f1 = next(o for o in r1.program.ops if o.name.startswith("F/"))
    f2 = next(o for o in r2.program.ops if o.name.startswith("F/"))
    assert f2.flops == pytest.approx(f1.flops / 4.0, rel=1e-12)


def test_pp_boundary_crosses_the_right_tier():
    """With 4-accel chips and tp=4, adjacent pipeline stages live on
    different chips of one node: the boundary hop rides the node tier."""
    fab = Fabric.cluster(32)
    r = training.simulate_training(TOY, global_batch=8, n_stages=2,
                                   n_microbatches=2, tp_degree=4,
                                   fabric=fab)
    x = [op for op in r.program.ops if op.name.startswith("xF/")]
    assert x and all(op.tier == "node" for op in x)
    # tp=1: adjacent stages share a chip -> legacy device transfer
    r2 = training.simulate_training(TOY, global_batch=8, n_stages=2,
                                    n_microbatches=2, fabric=fab)
    x2 = [op for op in r2.program.ops if op.name.startswith("xF/")]
    assert x2 and all(op.tier is None and op.bytes_in > 0 for op in x2)


def test_dp_overlap_across_stages():
    """Each stage's gradient all-reduce chains after ITS last backward,
    so the reduce phase of late stages overlaps earlier backwards: the
    pipelined step beats serial sum of (stage work + its reduce)."""
    fab = Fabric.cluster(16)
    r = training.simulate_training(TOY, global_batch=8, n_stages=4,
                                   n_microbatches=4, dp_degree=4,
                                   fabric=fab)
    dp_starts = sorted(e.start for e in r.engine.timeline.events
                       if "train/dp" in e.name)
    b_ends = sorted(e.start + e.duration
                    for e in r.engine.timeline.events
                    if e.name.startswith("B/"))
    assert dp_starts and dp_starts[0] < b_ends[-1]


def test_cluster_records_columns_and_sanity():
    rows = as_cluster_records(cluster_sweep(
        TOY, n_accel_grid=(8,), algos=("ring", "hierarchical"),
        placements=[(2, 2, 2), (8, 1, 1)], global_batch=16))
    assert len(rows) == 4
    need = {"n_accel", "dp_degree", "pp_degree", "tp_degree",
            "collective_algo", "step_time_s", "cluster_tokens_per_s",
            "replica_j", "cluster_j", "tco_usd_per_step",
            "tco_usd_per_mtok", "collective_s", "fabric"}
    for r in rows:
        assert need <= set(r)
        assert r["step_time_s"] > 0.0
        assert r["tco_usd_per_step"] > 0.0
        assert r["cluster_j"] >= r["replica_j"]


@pytest.mark.slow
def test_large_grid_hierarchical_never_loses_slow():
    """512-accel grid: hierarchical <= ring in every node/inter-spanning
    dp cell (the per-tier decomposition is the whole point)."""
    rows = as_cluster_records(cluster_sweep(
        TOY, n_accel_grid=(512,), algos=("ring", "hierarchical"),
        max_tp=4, max_pp=4, global_batch=32))
    by_cell = {}
    for r in rows:
        key = (r["dp_degree"], r["pp_degree"], r["tp_degree"])
        by_cell.setdefault(key, {})[r["collective_algo"]] = \
            r["step_time_s"]
    assert by_cell
    for key, cell in by_cell.items():
        assert cell["hierarchical"] <= cell["ring"] * (1.0 + 1e-9), key
