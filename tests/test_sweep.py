"""The sweep layer: shared plans, memoized lowerings, executors, records."""
import dataclasses

import pytest

from repro.apps.paper_graphs import build_paper_graph
from repro.configs.paper_nets import PAPER_NETS
from repro.sim import engine, ir
from repro.sim.sweep import (as_records, batched, clear_caches,
                             graph_digest, lower_graph, lower_hlo, sweep)

HLO = {"flops": 1e15, "dot_flops": 9e14, "bytes": 1e12,
       "collective_bytes": 1e10, "wire_bytes": 1.5e10,
       "transcendentals": 1e9, "collectives": {}, "n_while": 1,
       "custom_calls": {}}

CONFIGS = [
    engine.EngineConfig(n_workers=1, interface="dma"),
    engine.EngineConfig(n_workers=4, interface="acp", hbm_ports=2),
    engine.EngineConfig(n_workers=8, interface="hbm", hbm_ports=4,
                        host_dispatch_s=1e-6),
]


def _identical(a, b):
    assert a.makespan == b.makespan
    assert a.breakdown == b.breakdown
    assert a.energy == b.energy
    assert a.timeline.events == b.timeline.events


def test_sweep_matches_individual_runs():
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    prog = ir.from_graph(g, batch=1, max_tile_elems=2048)
    results = sweep(prog, CONFIGS)
    assert len(results) == len(CONFIGS)
    for cfg, res in zip(CONFIGS, results):
        assert res.config is cfg
        _identical(res, engine.run(prog, cfg))


@pytest.mark.parametrize("executor", ["serial", "thread", "process"])
def test_sweep_executors_agree(executor):
    prog = ir.from_hlo(HLO, n_ops=16)
    base = sweep(prog, CONFIGS, executor="serial")
    other = sweep(prog, CONFIGS, executor=executor)
    for a, b in zip(base, other):
        _identical(a, b)


def test_sweep_empty_and_bad_executor():
    prog = ir.from_hlo(HLO, n_ops=2)
    assert sweep(prog, []) == []
    with pytest.raises(ValueError):
        sweep(prog, CONFIGS, executor="carrier-pigeon")


def test_lower_graph_memoizes_on_digest_and_params():
    clear_caches()
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    p1 = lower_graph(g, batch=1, max_tile_elems=2048)
    p2 = lower_graph(g, batch=1, max_tile_elems=2048)
    assert p1 is p2                       # cache hit
    p3 = lower_graph(g, batch=1, max_tile_elems=4096)
    assert p3 is not p1                   # tile params are part of the key
    p4 = lower_graph(g, batch=4, max_tile_elems=2048)
    assert p4 is not p1                   # batch is part of the key
    # the key is the structural digest, not object identity: a freshly
    # built but identical graph hits the same cache entry
    g2 = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    assert graph_digest(g2) == graph_digest(g)
    assert lower_graph(g2, 1, 2048) is p1
    # and a structurally different graph misses
    g3 = build_paper_graph(
        PAPER_NETS[next(k for k in PAPER_NETS if k != "lenet5")], batch=1)
    assert graph_digest(g3) != graph_digest(g)
    assert lower_graph(g3, 1, 2048) is not p1


def test_graph_digest_is_stable_per_object_across_lowering():
    """``from_graph`` backfills weight-derived attrs in place; the digest
    is pinned at first sight of the object, so re-lowering the same graph
    keeps hitting its own entry instead of forking a post-mutation key."""
    clear_caches()
    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    d0 = graph_digest(g)
    p1 = lower_graph(g, batch=1, max_tile_elems=2048)
    assert graph_digest(g) == d0
    assert lower_graph(g, batch=1, max_tile_elems=2048) is p1


def test_lower_hlo_memoizes_on_content():
    clear_caches()
    p1 = lower_hlo(HLO, n_ops=8)
    assert lower_hlo(dict(HLO), n_ops=8) is p1      # equal content hits
    assert lower_hlo(HLO, n_ops=4) is not p1
    assert lower_hlo(dict(HLO, flops=2e15), n_ops=8) is not p1


def test_lowering_caches_are_true_lru(monkeypatch):
    """A hit refreshes recency: the hot entry survives eviction while the
    cold one is dropped (OrderedDict move_to_end semantics)."""
    import importlib
    # the package re-exports the sweep() function under the same name, so
    # plain ``import repro.sim.sweep as m`` would bind the function
    sweep_mod = importlib.import_module("repro.sim.sweep")
    clear_caches()
    monkeypatch.setattr(sweep_mod, "_CACHE_MAX", 2)
    hot = lower_hlo(HLO, n_ops=2)
    cold = lower_hlo(HLO, n_ops=3)
    assert lower_hlo(HLO, n_ops=2) is hot       # refresh 'hot'
    lower_hlo(HLO, n_ops=4)                     # evicts LRU = 'cold'
    assert lower_hlo(HLO, n_ops=2) is hot       # survived
    assert lower_hlo(HLO, n_ops=3) is not cold  # was evicted, re-lowered

    g = build_paper_graph(PAPER_NETS["lenet5"], batch=1)
    hot = lower_graph(g, batch=1, max_tile_elems=2048)
    cold = lower_graph(g, batch=2, max_tile_elems=2048)
    assert lower_graph(g, 1, 2048) is hot
    lower_graph(g, batch=3, max_tile_elems=2048)
    assert lower_graph(g, 1, 2048) is hot
    assert lower_graph(g, 2, 2048) is not cold
    clear_caches()


def test_process_pool_creation_failure_falls_back_to_serial(monkeypatch):
    """Platform/pool failures degrade to serial with identical results."""
    import concurrent.futures

    def refuse(*a, **k):
        raise OSError("no fork for you")

    monkeypatch.setattr(concurrent.futures, "ProcessPoolExecutor", refuse)
    prog = ir.from_hlo(HLO, n_ops=8)
    got = sweep(prog, CONFIGS, executor="process")
    for a, b in zip(got, sweep(prog, CONFIGS, executor="serial")):
        _identical(a, b)


def test_process_task_errors_propagate():
    """A genuine error raised by engine.run inside a worker is NOT
    swallowed by the pool-failure fallback: it reaches the caller with
    its own type (the old bare ``except Exception`` hid these)."""
    prog = ir.from_hlo(HLO, n_ops=4)
    bad = dataclasses.replace(CONFIGS[0], interface="carrier-pigeon")
    with pytest.raises(ValueError, match="interface"):
        sweep(prog, [CONFIGS[0], bad], executor="process")


def test_as_records_is_tidy():
    prog = ir.from_hlo(HLO, n_ops=4)
    rows = as_records(sweep(prog, CONFIGS))
    assert len(rows) == len(CONFIGS)
    for row, cfg in zip(rows, CONFIGS):
        assert row["interface"] == cfg.interface
        assert row["n_workers"] == cfg.n_workers
        assert row["makespan_s"] > 0
        assert set(row) >= {"program", "n_ops", "makespan_s", "transfer_s",
                            "total_j", "utilization", "bound",
                            "relaxation_err"}


def test_utilization_counts_provisioned_workers():
    """A worker that never receives an op still dilutes utilization: one
    1 ms op on an 8-worker config is 1/8 utilized, not 100%."""
    prog = ir.Program([ir.CostedOp("only", duration_s=1e-3)])
    res = engine.run(prog, engine.EngineConfig(n_workers=8))
    assert res.utilization() == pytest.approx(1.0 / 8.0)
    assert res.utilization("acc0") == pytest.approx(1.0)
    # saturated single worker stays 1.0
    res1 = engine.run(prog, engine.EngineConfig(n_workers=1))
    assert res1.utilization() == pytest.approx(1.0)


def test_batched_exact_on_fusion_resolvable_dag():
    """Parallel collective lanes are a DAG, but linear-run fusion resolves
    them to a small segment graph — batched() must price the whole grid
    exactly (lower == upper == engine.run) with relaxation_err == 0."""
    from repro.sim import hw
    fab = hw.Fabric.cluster(16)
    prog = ir.Program(
        list(ir.from_collective("all_reduce", 32e6, (0, 1, 2, 3), fab,
                                prefix="a").ops)
        + list(ir.from_collective("all_reduce", 32e6, (4, 5, 6, 7), fab,
                                  prefix="b").ops),
        name="parallel-lanes")
    plan = engine.prepare(prog)
    assert not plan.is_chain and engine.fusion_resolvable(plan)
    cfgs = [engine.EngineConfig(ici_bw=b, ici_lat_s=l, n_workers=4)
            for b in (25e9, 100e9, 400e9) for l in (0.0, 1e-6)]
    bs = batched(prog, cfgs, top_k=3)
    assert bs.exact and not bs.is_chain and bs.backend == "engine"
    import numpy as np
    assert np.array_equal(bs.lower, bs.upper)
    for m, c in zip(bs.makespans, cfgs):
        assert float(m) == engine.run(prog, c).makespan     # bit-identical
    assert len(bs.verified) == 3
    for v in bs.verified:
        assert v["relaxation_err"] == 0.0
        assert v["analytic_s"] == v["exact_s"]
    assert bs.best()["exact_s"] == min(float(m) for m in bs.makespans)
    # chain grids keep the exact flag through the analytic path
    chain = ir.from_hlo(HLO, n_ops=8)
    assert batched(chain, [engine.EngineConfig()], top_k=1).exact


def test_from_decode_shape_and_seriality():
    from repro.configs.gemma_2b import SMOKE
    prog = ir.from_decode(SMOKE, n_tokens=12, ops_per_token=4)
    assert len(prog.ops) == 48
    assert engine.prepare(prog).is_chain
    # KV growth: later tokens read strictly more bytes
    first = sum(op.bytes_in for op in prog.ops[:4])
    last = sum(op.bytes_in for op in prog.ops[-4:])
    assert last > first
    res = engine.run(prog, engine.EngineConfig())
    assert res.makespan > 0
