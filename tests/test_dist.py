"""Distribution utilities: gradient compression, MoE dispatch invariants,
interface/energy/simulator models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.dist.compress import dequantize_int8, quantize_int8


class _FakeMesh:
    shape = {}


def test_quantize_roundtrip_error_bounded():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (1000,)) * 3.0
    q, scale = quantize_int8(x, jax.random.PRNGKey(1))
    deq = dequantize_int8(q, scale, x.shape, x.size)
    err = jnp.abs(deq - x)
    # per-block max is 127*scale; quantization error <= scale (1 LSB)
    blocks = jnp.pad(x, (0, (-x.size) % 256)).reshape(-1, 256)
    lsb = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    assert float(jnp.max(err)) <= float(jnp.max(lsb)) * 1.01 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the running quantized sum tracks the true sum."""
    from repro.dist.compress import compressed_psum_grads
    g = {"w": jnp.ones((300,)) * 0.01}
    err = None
    total_q = jnp.zeros((300,))
    for i in range(20):
        out, err = compressed_psum_grads(g, _FakeMesh(), "data",
                                         jax.random.PRNGKey(i), err)
        total_q = total_q + out["w"]
    true = 20 * 0.01
    assert float(jnp.max(jnp.abs(total_q - true))) < 5e-4


def test_moe_dispatch_conservation():
    """Every surviving (token, slot) pair lands in exactly one buffer slot
    and is combined back with its router weight."""
    from repro.models.moe import _dispatch_indices
    T, k, E, C = 64, 2, 8, 16
    rng = np.random.default_rng(0)
    e_idx = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    buf_token, slot_of = _dispatch_indices(e_idx, E, 0, E, C)
    buf = np.asarray(buf_token)
    slots = np.asarray(slot_of)
    for t in range(T):
        for j in range(k):
            s = slots[t, j]
            if s < E * C:  # not dropped
                assert buf.reshape(-1)[s] == t
    # buffer slots hold only valid or sentinel tokens
    assert ((buf == T) | ((buf >= 0) & (buf < T))).all()


@given(T=st.sampled_from([8, 32, 64]), k=st.sampled_from([1, 2, 4]),
       E=st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_moe_capacity_drops_only_overflow(T, k, E):
    from repro.models.moe import _dispatch_indices
    import math
    C = max(1, math.ceil(T * k * 1.25 / E))
    rng = np.random.default_rng(T * k * E)
    e_idx = jnp.asarray(rng.integers(0, E, (T, k)), jnp.int32)
    buf_token, slot_of = _dispatch_indices(e_idx, E, 0, E, C)
    slots = np.asarray(slot_of)
    # per expert, at most C slots used
    used = np.asarray(buf_token)
    assert ((used != T).sum(axis=1) <= C).all()


def test_simulator_roofline_terms():
    from repro.core.simulator import roofline
    from repro.configs import get_config
    from repro.core.config import SHAPE_BY_NAME
    hlo = {"flops": 1e15, "dot_flops": 9e14, "bytes": 1e12,
           "collective_bytes": 1e10, "collectives": {}, "n_while": 1,
           "custom_calls": {}}
    cfg = get_config("tinyllama_1_1b")
    rl = roofline(hlo, cfg, SHAPE_BY_NAME["train_4k"], 256)
    assert rl.compute_s == pytest.approx(1e15 / 197e12)
    assert rl.memory_s == pytest.approx(1e12 / 819e9)
    assert rl.collective_s == pytest.approx(1e10 / 50e9)
    assert rl.bound == "compute"
    assert 0 < rl.roofline_fraction <= 1.0


def test_interfaces_acp_beats_dma():
    from repro.core.interfaces import acp_transfer, dma_transfer
    for nbytes in (1e5, 1e7, 1e8):
        d = dma_transfer(nbytes, n_transfers=8)
        a = acp_transfer(nbytes, resident_fraction=1.0)
        assert a.seconds < d.seconds
        assert a.energy_j < d.energy_j


def test_timeline_utilization():
    from repro.core.timeline import Timeline
    tl = Timeline()
    tl.add("acc0", "a", 0.0, 1.0)
    tl.add("acc1", "b", 0.0, 0.5)
    assert tl.makespan == 1.0
    assert tl.utilization() == pytest.approx(0.75)
    assert "acc0" in tl.ascii()
