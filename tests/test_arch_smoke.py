"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement).  The FULL configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.config import SHAPES, cell_is_runnable
from repro.models import transformer as T
from repro.train import TrainConfig, init_train_state, make_train_step


def _batch(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    b = {"tokens": jnp.asarray(toks[:, :S]),
         "labels": jnp.asarray(toks[:, 1:S + 1])}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder.n_ctx, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    logits, aux = T.train_forward(cfg, params, b)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    params, opt, _, _ = init_train_state(cfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, TrainConfig(lr=1e-3, warmup=1,
                                            total_steps=10))
    b = _batch(cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, b, jnp.ones(
        (), jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(
            lambda a, b_: a.astype(jnp.float32) - b_.astype(jnp.float32),
            params, params2), 0.0)
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:  # avoid capacity-drop nondeterminism
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, :S]}
    if cfg.family == "encdec":
        fr = jnp.ones((B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32) * .1
        full["frames"] = fr
        pre["frames"] = fr
    if cfg.family == "vlm":
        pa = jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.float32) * .1
        full["patches"] = pa
        pre["patches"] = pa
    ref, _ = T.train_forward(cfg, params, full)
    logits_p, cache = T.prefill_forward(cfg, params, pre,
                                        max_seq=S + cfg.n_patches + 4)
    pos = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_d, _ = T.decode_forward(cfg, params, cache, toks[:, S:S + 1], pos)
    ref32 = np.asarray(ref, np.float32)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               ref32[:, S - 1], rtol=0.06, atol=0.08)
    np.testing.assert_allclose(np.asarray(logits_d[:, 0], np.float32),
                               ref32[:, S], rtol=0.08, atol=0.15)


def test_all_cells_defined():
    """Every (arch x shape) cell resolves to run-or-documented-skip."""
    n_run = n_skip = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_is_runnable(cfg, shape)
            if ok:
                n_run += 1
            else:
                assert why
                n_skip += 1
    assert n_run + n_skip == 40
    assert n_skip == 7  # 7 pure full-attention archs skip long_500k
