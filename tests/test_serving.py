"""Trace-driven serving simulation: determinism, hand-computed accounting,
policy edge cases, and the continuous-vs-static throughput claim."""
import math

import pytest

from repro.core.config import ModelConfig
from repro.serve.policy import (ContinuousBatching, DynamicBatching,
                                StaticBatching, get_policy)
from repro.sim import engine, ir
from repro.sim.report import latency_stats, percentile
from repro.sim.serving import (Request, load_trace, poisson_trace,
                               bursty_trace, save_trace, serving_sweep,
                               simulate_serving, as_serving_records,
                               trace_from_records)

TOY = ModelConfig(name="toy", family="dense", n_layers=2, d_model=8,
                  n_heads=2, n_kv_heads=2, d_ff=16, vocab=32, head_dim=4)


# ---------------------------------------------------------------------------
# from_serving_step accounting (hand-computed)


def test_from_serving_step_accounting():
    """Byte/flop accounting of one mixed step vs the documented formulas."""
    bpp = 2.0
    prog = ir.from_serving_step(TOY, prefill_lens=(3, 5),
                                decode_positions=(7, 9), step=2,
                                bytes_per_param=bpp)
    assert [op.name for op in prog.ops] == ["step2/prefill", "step2/decode"]
    pre, dec = prog.ops
    assert dec.deps == ("step2/prefill",)

    n_active = float(TOY.active_param_count())
    kv_dim = TOY.n_kv_heads * TOY.resolved_head_dim        # 2 * 4 = 8
    n_attn = TOY.n_layers                                  # 2
    assert kv_dim == 8 and n_attn == 2
    weight_bytes = n_active * bpp
    kv_entry = kv_dim * n_attn * bpp                       # 32 B per token

    # prefill: 3+5 tokens dense + causal attention 3*2/2 + 5*4/2 = 3 + 10
    assert pre.flops == 2.0 * n_active * 8 + 4.0 * n_attn * kv_dim * 13
    assert pre.dot_flops == pre.flops
    assert pre.bytes_in == weight_bytes          # weights once, on first op
    assert pre.bytes_out == kv_entry * 8         # one KV entry per token

    # decode: 2 slots at positions 7 and 9
    assert dec.flops == 2.0 * n_active * 2 + 4.0 * n_attn * kv_dim * 16
    assert dec.bytes_in == 2.0 * n_attn * kv_dim * 16 * bpp   # KV re-read
    assert dec.bytes_out == kv_entry * 2


def test_from_serving_step_decode_only_charges_weights():
    prog = ir.from_serving_step(TOY, decode_positions=(4,), step=0)
    (dec,) = prog.ops
    n_active = float(TOY.active_param_count())
    assert dec.deps == ()
    assert dec.bytes_in == n_active * 2.0 + 2.0 * 2 * 8 * 4 * 2.0
    # and matches the from_decode convention at the same position
    tok = ir.from_decode(TOY, n_tokens=1, seq_len=4, ops_per_token=1).ops[0]
    assert dec.flops == tok.flops
    assert dec.bytes_in == tok.bytes_in
    assert dec.bytes_out == tok.bytes_out


def test_from_serving_step_empty():
    assert len(ir.from_serving_step(TOY).ops) == 0


# ---------------------------------------------------------------------------
# scheduler: hand-checked 2-request trace


def test_two_request_static_schedule():
    """2 simultaneous requests, static max_batch=2, outputs (2, 3):
    prefill step + 2 decode steps; the short request pads the last one."""
    trace = [Request(0, 0.0, prompt_len=4, output_len=2),
             Request(1, 0.0, prompt_len=6, output_len=3)]
    res = simulate_serving(TOY, trace, StaticBatching(max_batch=2))
    assert [op.name for op in res.program.ops] == \
        ["step0/prefill", "step1/decode", "step2/decode"]
    assert [(s.n_prefill, s.n_decode, s.n_active) for s in res.steps] == \
        [(2, 0, 0), (0, 2, 2), (0, 2, 1)]          # last step: 1 padded slot
    # positions advance batch-wide from the prompt lengths
    assert res.program.ops[1].flops == \
        2.0 * TOY.active_param_count() * 2 + 4.0 * 2 * 8 * (4 + 6)
    assert res.program.ops[2].flops == \
        2.0 * TOY.active_param_count() * 2 + 4.0 * 2 * 8 * (5 + 7)
    a, b = res.requests
    assert a.first_token_s == b.first_token_s == res.steps[0].end_s
    assert a.finish_s == res.steps[1].end_s
    assert b.finish_s == res.steps[2].end_s == res.makespan_s
    assert res.total_tokens == 2 + 3
    assert res.occupancy == pytest.approx((2 + 1) / (2 * 2))


def test_serving_determinism_bit_identical():
    trace = poisson_trace(24, 40.0, seed=7)
    for policy in (StaticBatching(4), DynamicBatching(4, max_wait_s=0.02),
                   ContinuousBatching(4)):
        a = simulate_serving(TOY, trace, policy)
        b = simulate_serving(TOY, trace, policy)
        assert a.engine.makespan == b.engine.makespan
        assert a.engine.timeline.events == b.engine.timeline.events
        assert a.engine.energy == b.engine.energy
        assert a.makespan_s == b.makespan_s
        assert a.requests == b.requests
        assert a.steps == b.steps


@pytest.mark.parametrize("config", [
    engine.EngineConfig(),
    engine.EngineConfig(interface="acp", host_dispatch_s=1e-6),
    engine.EngineConfig(interface="dma", hbm_ports=2, host_bw=20e9),
])
def test_scheduler_clock_matches_engine_bitwise(config):
    """The scheduler's busy accumulation IS the engine's chain prefix sum."""
    trace = poisson_trace(16, 100.0, seed=3)
    for kind in ("static", "dynamic", "continuous"):
        res = simulate_serving(TOY, trace, get_policy(kind, max_batch=4),
                               config)
        assert engine.prepare(res.program).is_chain
        assert res.busy_s == res.engine.makespan
        assert res.makespan_s >= res.busy_s


# ---------------------------------------------------------------------------
# policy edge cases


def test_empty_trace():
    for kind in ("static", "dynamic", "continuous"):
        res = simulate_serving(TOY, [], get_policy(kind))
        assert res.steps == [] and res.requests == []
        assert len(res.program.ops) == 0
        assert res.makespan_s == 0.0 and res.engine.makespan == 0.0
        assert res.throughput_tok_s == 0.0 and res.occupancy == 0.0
        assert res.stats()["n_steps"] == 0


def test_dynamic_max_wait_expiry_launches_partial_batch():
    """A lone request must not wait forever for a full batch: the max-wait
    deadline launches a 1-request batch; the later request forms its own."""
    trace = [Request(0, 0.0, 4, 2), Request(1, 1.0, 4, 2)]
    res = simulate_serving(TOY, trace, DynamicBatching(max_batch=8,
                                                       max_wait_s=0.01))
    prefills = [s for s in res.steps if s.n_prefill]
    assert [s.n_prefill for s in prefills] == [1, 1]
    assert prefills[0].start_s == pytest.approx(0.01)
    assert prefills[1].start_s >= 1.0
    # static with the same trace would batch them together at end-of-trace
    res_static = simulate_serving(TOY, trace, StaticBatching(max_batch=8))
    assert [s.n_prefill for s in res_static.steps if s.n_prefill] == [2]


def test_continuous_evicts_at_end_of_output_and_reuses_slot():
    """max_batch=1: the second request can only start once the first's
    output completes (eviction frees the slot)."""
    trace = [Request(0, 0.0, 4, 5), Request(1, 0.0, 4, 3)]
    res = simulate_serving(TOY, trace, ContinuousBatching(max_batch=1))
    a, b = res.requests
    assert b.first_token_s >= a.finish_s
    assert res.total_tokens == 8
    # every decode step carries exactly the one live slot
    assert all(s.n_decode == 1 for s in res.steps if s.n_decode)


def test_continuous_admits_into_freed_slots_mid_flight():
    trace = [Request(0, 0.0, 4, 2), Request(1, 0.0, 4, 8),
             Request(2, 0.0, 4, 8)]
    res = simulate_serving(TOY, trace, ContinuousBatching(max_batch=2))
    c = res.requests[2]
    a = res.requests[0]
    # request 2 was admitted right after request 0 finished, well before
    # request 1 (which still had output budget) released its slot
    assert a.finish_s <= c.first_token_s < res.requests[1].finish_s


def test_static_holds_padded_slots_until_batch_drains():
    trace = [Request(0, 0.0, 4, 1), Request(1, 0.0, 4, 6)]
    res = simulate_serving(TOY, trace, StaticBatching(max_batch=2))
    # output_len=1 finishes at prefill; the padded slot still occupies the
    # batch for all 5 decode steps
    decode_steps = [s for s in res.steps if s.n_decode]
    assert all(s.n_decode == 2 for s in decode_steps)
    assert [s.n_active for s in decode_steps] == [1] * 5
    assert res.requests[0].finish_s == res.requests[0].first_token_s
    assert res.requests[0].tpot_s == 0.0


# ---------------------------------------------------------------------------
# the end-to-end claim + the sweep grid


def test_continuous_beats_static_at_saturation():
    """Acceptance: at an arrival rate that saturates the server,
    continuous batching yields strictly higher simulated throughput."""
    from repro.configs.gemma_2b import FULL as GEMMA
    trace = poisson_trace(48, 500.0, seed=0)
    cont = simulate_serving(GEMMA, trace, ContinuousBatching(max_batch=8))
    stat = simulate_serving(GEMMA, trace, StaticBatching(max_batch=8))
    assert cont.throughput_tok_s > stat.throughput_tok_s
    assert cont.occupancy > stat.occupancy
    # and first tokens come back sooner under iteration-level admission
    assert cont.stats()["ttft_p50"] < stat.stats()["ttft_p50"]


def test_serving_sweep_grid_and_records():
    policies = [StaticBatching(4), ContinuousBatching(4)]
    results = serving_sweep(TOY, policies, [50.0, 200.0], n_requests=12,
                            seed=1)
    assert len(results) == 4
    assert [r.meta["rate_rps"] for r in results] == [50.0, 50.0,
                                                     200.0, 200.0]
    rows = as_serving_records(results)
    assert {r["policy"] for r in rows} == {"static", "continuous"}
    for row in rows:
        assert set(row) >= {"rate_rps", "throughput_tok_s", "ttft_p50",
                            "ttft_p99", "tpot_p50", "occupancy",
                            "makespan_s", "engine_makespan_s"}


# ---------------------------------------------------------------------------
# traces, policies, stats helpers


def test_trace_generators_deterministic_and_sorted():
    a = poisson_trace(32, 25.0, seed=5)
    assert a == poisson_trace(32, 25.0, seed=5)
    assert a != poisson_trace(32, 25.0, seed=6)
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(a, a[1:]))
    b = bursty_trace(32, 25.0, seed=5)
    assert b == bursty_trace(32, 25.0, seed=5)
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in a + b)


def test_trace_round_trip(tmp_path):
    trace = poisson_trace(8, 10.0, seed=2)
    p = tmp_path / "trace.jsonl"
    save_trace(p, trace)
    assert load_trace(p) == trace
    # JSON-array form loads too
    q = tmp_path / "trace.json"
    q.write_text("[" + ",".join(
        '{"arrival_s": %r, "prompt_len": %d, "output_len": %d}'
        % (r.arrival_s, r.prompt_len, r.output_len) for r in trace) + "]")
    loaded = load_trace(q)
    assert [(r.arrival_s, r.prompt_len, r.output_len) for r in loaded] == \
        [(r.arrival_s, r.prompt_len, r.output_len) for r in trace]
    assert trace_from_records([{"arrival_s": 1.5, "prompt_len": 0,
                                "output_len": 0}]) == \
        [Request(0, 1.5, 1, 1)]                 # lengths clamp to >= 1


def test_duplicate_rids_rejected():
    """Metrics are keyed on rid — a duplicate must fail loudly, not
    silently collapse two requests into one latency record."""
    rec = {"rid": 5, "arrival_s": 0.0, "prompt_len": 4, "output_len": 2}
    with pytest.raises(ValueError, match="duplicate rid"):
        trace_from_records([rec, dict(rec, arrival_s=0.5)])
    with pytest.raises(ValueError, match="duplicate rid"):
        simulate_serving(TOY, [Request(5, 0.0, 4, 2),
                               Request(5, 0.5, 4, 2)],
                         StaticBatching(max_batch=2))


# ---------------------------------------------------------------------------
# hypothesis properties (skipped automatically when hypothesis is absent)


from _hyp import given, settings, st  # noqa: E402


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 48), rate=st.floats(0.5, 500.0),
       seed=st.integers(0, 2**16), kind=st.sampled_from(["poisson",
                                                         "bursty"]))
def test_trace_generator_properties(n, rate, seed, kind):
    """Arrivals are sorted and non-negative, lengths are >= 1, and the
    generators are pure functions of their arguments — for ANY
    (n, rate, seed)."""
    gen = poisson_trace if kind == "poisson" else bursty_trace
    trace = gen(n, rate, seed=seed)
    assert len(trace) == n
    assert all(r.arrival_s >= 0.0 for r in trace)
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(trace, trace[1:]))
    assert all(r.prompt_len >= 1 and r.output_len >= 1 for r in trace)
    assert [r.rid for r in trace] == list(range(n))
    assert trace == gen(n, rate, seed=seed)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 32), rate=st.floats(1.0, 300.0),
       seed=st.integers(0, 2**16))
def test_trace_round_trip_property(n, rate, seed):
    """save_trace -> load_trace is the identity, bit for bit, through
    BOTH record formats (JSON-lines and a JSON array): float fields
    survive exactly (json emits repr, repr round-trips IEEE doubles)."""
    import json
    import tempfile
    trace = poisson_trace(n, rate, seed=seed)
    with tempfile.TemporaryDirectory() as d:
        p = f"{d}/trace.jsonl"
        save_trace(p, trace)
        assert load_trace(p) == trace              # JSONL, bit-identical
        q = f"{d}/trace.json"
        with open(p) as f:
            records = [json.loads(ln) for ln in f]
        with open(q, "w") as f:
            json.dump(records, f)
        assert load_trace(q) == trace              # JSON array, same bits


def test_get_policy_registry():
    assert get_policy("dynamic", max_batch=16, max_wait_s=0.5).max_wait_s \
        == 0.5
    with pytest.raises(KeyError):
        get_policy("clairvoyant")


def test_percentile_and_latency_stats():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    import numpy as np
    assert percentile(xs, 99) == pytest.approx(
        float(np.percentile(xs, 99)))
    s = latency_stats(xs)
    assert s["n"] == 4 and s["mean"] == 2.5 and s["max"] == 4.0
    empty = latency_stats([])
    assert empty["n"] == 0 and empty["p99"] == 0.0
    assert not any(math.isnan(v) for v in empty.values())
