"""Guards over the committed experiment artifacts: the dry-run table is
complete, the recorded §Perf iterations actually improved their cells,
and the BENCH_*.json benchmark grids at the repo root keep their golden
schema — required keys, finite positive timings, and the derived claims
(speedups >= 1, continuous >= static at saturation, 1F1B-vs-GPipe and
bubble-vs-bound relations) — so a benchmark refactor cannot silently
ship a malformed artifact."""
import json
import math
from pathlib import Path

import pytest

DRYRUN = Path("experiments/dryrun/results.json")
PERF = Path("experiments/perf_iters.json")
ROOFLINE = Path("experiments/roofline_single_pod.json")
BENCH_ENGINE = Path("BENCH_engine.json")
BENCH_SERVING = Path("BENCH_serving.json")
BENCH_SOC = Path("BENCH_soc.json")
BENCH_TRAINING = Path("BENCH_training.json")
BENCH_DSE = Path("BENCH_dse.json")
BENCH_FLEET = Path("BENCH_fleet.json")
BENCH_CLUSTER = Path("BENCH_cluster.json")
BENCH_CALIBRATION = Path("BENCH_calibration.json")


def _finite_pos(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x) and x > 0.0


@pytest.mark.skipif(not DRYRUN.exists(), reason="sweep not present")
def test_dryrun_sweep_complete():
    res = json.loads(DRYRUN.read_text())
    ok = [r for r in res.values() if r["status"] == "ok"]
    skip = [r for r in res.values() if r["status"] == "skip"]
    err = [r for r in res.values() if r["status"] == "error"]
    assert len(err) == 0, err
    assert len(ok) == 66   # 33 runnable cells x 2 meshes
    assert len(skip) == 14  # 7 full-attention long_500k x 2 meshes
    # every ok cell has the full record
    for r in ok:
        assert r["memory"]["temp_bytes"] >= 0
        assert r["hlo"]["flops"] > 0
        assert r["hlo"]["bytes"] > 0


@pytest.mark.skipif(not ROOFLINE.exists(), reason="table not present")
def test_roofline_table_covers_40_cells():
    table = json.loads(ROOFLINE.read_text())
    assert len(table) == 40  # 33 ok + 7 documented skips
    ok = [r for r in table.values() if r["status"] == "ok"]
    assert len(ok) == 33
    for r in ok:
        assert r["bound"] in ("compute", "memory", "collective")
        assert r["compute_s"] > 0 and r["memory_s"] > 0


# ---------------------------------------------------------------------------
# golden schemas of the BENCH_*.json grids (repo root)


@pytest.mark.skipif(not BENCH_ENGINE.exists(), reason="bench not present")
def test_bench_engine_schema():
    b = json.loads(BENCH_ENGINE.read_text())
    assert set(b) >= {"cases", "budget_s", "sweep_8cfg_decode_5k",
                      "recorded", "note"}
    assert b["cases"], "no recorded cases"
    for name, case in b["cases"].items():
        assert case["n_ops"] > 0, name
        assert _finite_pos(case["engine_s"]), name
        assert _finite_pos(case["reference_s"]), name
        # the engine must never have regressed below the frozen PR base
        assert case["speedup"] >= 1.0, name
        # the recorded column is derived from the (rounded) timings
        assert case["speedup"] == pytest.approx(
            case["reference_s"] / case["engine_s"], rel=0.05), name
    assert all(_finite_pos(v) for v in b["budget_s"].values())
    # the sweep case scales at least as well as serial execution
    sw = b["sweep_8cfg_decode_5k"]
    assert _finite_pos(sw["sweep_s"]) and sw["speedup"] >= 1.0


@pytest.mark.skipif(not BENCH_SERVING.exists(), reason="bench not present")
def test_bench_serving_schema():
    b = json.loads(BENCH_SERVING.read_text())
    assert set(b) >= {"model", "n_requests", "config", "grid", "recorded"}
    grid = b["grid"]
    assert grid, "empty serving grid"
    required = {"policy", "rate_rps", "makespan_s", "busy_s",
                "engine_makespan_s", "throughput_tok_s", "occupancy",
                "ttft_p50", "ttft_p99", "tpot_p50", "latency_p99",
                "total_j"}
    by_cell = {}
    for rec in grid:
        assert required <= set(rec), rec.get("policy")
        assert _finite_pos(rec["makespan_s"])
        assert _finite_pos(rec["throughput_tok_s"])
        assert all(math.isfinite(rec[k]) and rec[k] >= 0.0
                   for k in required - {"policy"})
        # the co-simulation invariant survives serialization
        assert rec["busy_s"] == rec["engine_makespan_s"]
        assert rec["makespan_s"] >= rec["busy_s"]
        assert 0.0 <= rec["occupancy"] <= 1.0
        by_cell[(rec["policy"], rec["rate_rps"])] = rec
    # the recorded headline claim: continuous beats static at the
    # saturating (highest) arrival rate
    rates = sorted({r["rate_rps"] for r in grid})
    top = rates[-1]
    assert by_cell[("continuous", top)]["throughput_tok_s"] > \
        by_cell[("static", top)]["throughput_tok_s"]
    # the monotone speedup column: continuous batching's gain over static
    # grows with offered load (that is WHY it exists; dynamic is allowed
    # to sag at saturation — max-wait queueing is a real effect)
    gains = [by_cell[("continuous", rate)]["throughput_tok_s"]
             / by_cell[("static", rate)]["throughput_tok_s"]
             for rate in rates]
    assert gains == sorted(gains), gains


@pytest.mark.skipif(not BENCH_SOC.exists(), reason="bench not present")
def test_bench_soc_schema():
    b = json.loads(BENCH_SOC.read_text())
    assert set(b) >= {"records", "budget_s", "grid", "recorded"}
    g = b["grid"]
    want = len(g["frontends"]) * len(g["n_accels"]) * len(g["link_ports"])
    assert len(b["records"]) == want, "incomplete SoC grid"
    for rec in b["records"]:
        assert _finite_pos(rec["makespan_s"]), rec["topology"]
        assert _finite_pos(rec["total_j"]), rec["topology"]
        assert 0.0 <= rec["frontend_util"] <= 1.0
        assert 0.0 <= rec["accel_util_mean"] <= 1.0
        assert rec["bound"] in ("compute", "memory", "collective")
        assert rec["n_accels"] in g["n_accels"]
    assert all(_finite_pos(v) for v in b["budget_s"].values())


@pytest.mark.skipif(not BENCH_TRAINING.exists(), reason="bench not present")
def test_bench_training_schema():
    b = json.loads(BENCH_TRAINING.read_text())
    assert set(b) >= {"records", "budget_s", "grid", "recorded"}
    g = b["grid"]
    want = (len(g["models"]) * len(g["schedules"]) * len(g["n_stages"])
            * len(g["n_microbatches"]))
    assert len(b["records"]) == want, "incomplete training grid"
    by_cell = {}
    for rec in b["records"]:
        key = (rec["model"], rec["schedule"], rec["n_stages"],
               rec["n_microbatches"])
        by_cell[key] = rec
        assert _finite_pos(rec["step_time_s"]), key
        assert _finite_pos(rec["tokens_per_s"]), key
        assert 0.0 <= rec["bubble_fraction"] < 1.0, key
        assert rec["bubble_bound"] == pytest.approx(
            (rec["n_stages"] - 1)
            / (rec["n_microbatches"] + rec["n_stages"] - 1)), key
        assert 0.0 < rec["stage_util_mean"] <= 1.0, key
    for (model, schedule, p, m), rec in by_cell.items():
        # a single stage has no pipeline bubble, deeper pipes have more
        if p == 1:
            assert rec["bubble_fraction"] < 0.05, (model, schedule)
        # the analytic bound is monotone in m at fixed p — and the
        # recorded bound column must follow it
        if m > min(g["n_microbatches"]):
            prev = by_cell[(model, schedule, p, min(g["n_microbatches"]))]
            assert rec["bubble_bound"] <= prev["bubble_bound"]
    assert all(_finite_pos(v) for v in b["budget_s"].values())


@pytest.mark.skipif(not BENCH_DSE.exists(), reason="bench not present")
def test_bench_dse_schema():
    b = json.loads(BENCH_DSE.read_text())
    assert set(b) >= {"speedup", "dag_fidelity", "port_study", "budget_s",
                      "recorded", "note"}
    sp = b["speedup"]
    assert sp["n_configs"] >= 1024 and sp["n_ops"] >= 5000
    assert _finite_pos(sp["batched_s"]) and _finite_pos(sp["process_s"])
    # the recorded headline claim: the analytic batch beats the
    # process-pool engine sweep by the acceptance floor
    assert sp["speedup_vs_process"] >= 50.0
    assert sp["speedup_vs_process"] == pytest.approx(
        sp["process_s"] / sp["batched_s"], rel=0.05)
    # on chains the model is the engine: zero relaxation error, and the
    # analytic winner is the true winner
    assert sp["max_verified_relaxation_err"] == 0.0
    assert sp["best_matches_exact"] is True
    fid = b["dag_fidelity"]
    assert fid["bracket_holds"] is True
    assert math.isfinite(fid["lb_err_mean"]) and fid["lb_err_mean"] >= 0.0
    assert fid["lb_err_max"] < 1.0          # lower bound stays positive
    assert fid["ub_over_exact_mean"] >= 1.0
    ps = b["port_study"]
    assert len(ps["grid_exact_s"]) == len(ps["grid_ports"])
    assert all(_finite_pos(e) for e in ps["grid_exact_s"])
    assert _finite_pos(ps["opt_exact_s"]) and _finite_pos(ps["grid_best_s"])
    # optimize lands within 2% of the exact grid best (acceptance gate)
    assert abs(ps["within_frac"]) <= 0.02
    assert ps["knee_ports"] in ps["grid_ports"]
    assert all(_finite_pos(v) for v in b["budget_s"].values())


@pytest.mark.skipif(not BENCH_FLEET.exists(), reason="bench not present")
def test_bench_fleet_schema():
    b = json.loads(BENCH_FLEET.read_text())
    assert set(b) >= {"headline", "headline_quick", "speedup",
                      "bit_identity", "conservation", "fleet_grid",
                      "autoscale", "budget_s", "recorded", "note"}
    hl = b["headline"]
    assert hl["n_requests"] >= 1_000_000
    assert _finite_pos(hl["wall_s"]) and hl["wall_s"] <= 20.0
    # the recorded headline claim: >= 50k simulated requests/s
    assert hl["replay_rate_rps"] >= 50_000.0
    assert hl["replay_rate_rps"] == pytest.approx(
        hl["n_requests"] / hl["wall_s"], rel=0.05)
    assert 0.0 < hl["memo_hit_rate"] <= 1.0
    assert 0.0 <= hl["occupancy"] <= 1.0
    assert 0.0 <= hl["slo_attainment"] <= 1.0
    assert hl["n_steps"] > 0 and hl["n_replicas"] >= 1
    sp = b["speedup"]
    # memoization must actually pay, and must not change the arithmetic
    assert sp["speedup"] >= 10.0
    assert sp["speedup"] == pytest.approx(
        sp["unmemoized_s"] / sp["replay_s"], rel=0.05)
    assert sp["bit_identical"] is True
    assert b["bit_identity"]["bit_identical"] is True
    assert b["conservation"]["all_served_once"] is True
    for rec in b["fleet_grid"]:
        assert rec["router"] in ("round_robin", "least_outstanding",
                                 "session_affinity")
        assert rec["n_replicas"] >= 1
        assert 0.0 <= rec["slo_attainment"] <= 1.0
        assert _finite_pos(rec["throughput_req_s"])
        assert _finite_pos(rec["cost_per_token_j"])
    # more replicas never hurt SLO attainment on the shared trace
    by_router = {}
    for rec in b["fleet_grid"]:
        by_router.setdefault(rec["router"], []).append(
            (rec["n_replicas"], rec["slo_attainment"]))
    for router, cells in by_router.items():
        cells.sort()
        slos = [s for _, s in cells]
        assert slos == sorted(slos), (router, slos)
    asc = b["autoscale"]
    assert asc["n_scale_events"] >= 1
    assert asc["peak_replicas"] >= 2          # the burst forced a scale-up
    assert all(_finite_pos(v) for v in b["budget_s"].values())


@pytest.mark.skipif(not PERF.exists(), reason="perf log not present")
def test_hillclimb_confirmed_improvements():
    perf = json.loads(PERF.read_text())

    def mem(key):
        return perf[key]["roofline"]["memory_s"]

    # cell A: windowed attention improved gemma3 train + prefill
    base = mem("gemma3_1b|train_4k||mb1")
    best = mem("gemma3_1b|train_4k|attn_remat_chunk,windowed_attention|mb1")
    assert best < 0.6 * base
    # cell B: Megatron-SP improved internvl2
    base = mem("internvl2_26b|train_4k||mb1")
    best = mem("internvl2_26b|train_4k|attn_remat_chunk,"
               "seq_sharded_residual|mb1")
    assert best < 0.6 * base
    # cell C: the refutations are recorded (chunked made it worse)
    base = mem("falcon_mamba_7b|train_4k||mb1")
    worse = mem("falcon_mamba_7b|train_4k|ssm_impl=chunked|mb1")
    assert worse > base


@pytest.mark.skipif(not BENCH_CLUSTER.exists(), reason="bench not present")
def test_bench_cluster_schema():
    b = json.loads(BENCH_CLUSTER.read_text())
    assert set(b) >= {"cluster_grid", "cheapest_under_target", "bounds",
                      "hier_vs_ring", "single_tier_identity", "budget_s",
                      "recorded", "note"}
    # the recorded correctness probes must all hold
    assert b["bounds"]["exact"] is True
    assert b["bounds"]["worst_rel_err"] <= 1e-12
    assert b["hier_vs_ring"]["all_hold"] is True
    sid = b["single_tier_identity"]
    assert sid["no_dp_bit_identical"] is True
    assert sid["dp_ring_matches"] is True

    # the grid is committed columnar (compact artifact format): a column
    # list plus one row array per cell, floats rounded to 6 significant
    # digits — decode it back to records before the content checks
    g = b["cluster_grid"]
    assert set(g) == {"columns", "rows"}
    assert g["rows"] and all(len(r) == len(g["columns"])
                             for r in g["rows"])
    grid = [dict(zip(g["columns"], r)) for r in g["rows"]]
    need = {"model", "n_accel", "dp_degree", "pp_degree", "tp_degree",
            "collective_algo", "step_time_s", "cluster_tokens_per_s",
            "cluster_j", "tco_usd_per_step", "tco_usd_per_mtok",
            "speedup", "collective_s", "fabric"}
    assert grid
    for rec in grid:
        assert need <= set(rec), rec.get("program")
        assert _finite_pos(rec["step_time_s"])
        assert _finite_pos(rec["cluster_tokens_per_s"])
        assert _finite_pos(rec["tco_usd_per_step"])
        assert _finite_pos(rec["speedup"])
        assert rec["collective_algo"] in ("ring", "tree", "hierarchical")
        assert (rec["dp_degree"] * rec["pp_degree"] * rec["tp_degree"]
                == rec["n_accel"])
    # the acceptance sweep: >= 512 accelerators with all three degrees on
    assert any(rec["n_accel"] >= 512 and rec["dp_degree"] > 1
               and rec["pp_degree"] > 1 and rec["tp_degree"] > 1
               for rec in grid)
    # hierarchical <= ring cell-by-cell on node/inter-spanning dp groups
    cells = {}
    for rec in grid:
        key = (rec["model"], rec["n_accel"], rec["dp_degree"],
               rec["pp_degree"], rec["tp_degree"])
        cells.setdefault(key, {})[rec["collective_algo"]] = \
            rec["step_time_s"]
    assert cells
    for key, cell in cells.items():
        if "ring" in cell and "hierarchical" in cell:
            # 2e-6 headroom: the committed grid rounds to 6 significant
            # digits, so equal-to-rounding cells may differ by ~5e-7 rel
            assert cell["hierarchical"] <= cell["ring"] * (1 + 2e-6), key
    # the headline question has an answer for both models
    tgt = b["cheapest_under_target"]
    assert _finite_pos(tgt["target_step_s"])
    for model, best in tgt.items():
        if model == "target_step_s" or best is None:
            continue
        assert best["step_time_s"] <= tgt["target_step_s"]
        assert _finite_pos(best["tco_usd_per_step"])
    assert all(_finite_pos(v) for v in b["budget_s"].values())


@pytest.mark.skipif(not BENCH_CALIBRATION.exists(),
                    reason="bench not present")
def test_bench_calibration_schema():
    b = json.loads(BENCH_CALIBRATION.read_text())
    assert set(b) >= {"backend", "interpret", "samples", "kernels",
                      "improved", "n_improved", "budget_s", "recorded",
                      "note"}
    assert set(b["kernels"]) == {"matmul", "attention", "mamba"}
    for name, k in b["kernels"].items():
        assert k["n_samples"] >= 2, name
        assert _finite_pos(k["roofline_mape"]), name
        assert _finite_pos(k["fitted_mape"]), name
        # the measured table reproduces its own samples bit-exactly
        assert k["table_max_rel_err"] == 0.0, name
        for key, v in k["fitted"].items():
            assert v is None or (_finite_pos(v) or v == 0.0), (name, key)
    for s in b["samples"]:
        assert {"kernel", "kind", "shape", "flops", "bytes",
                "measured_s"} <= set(s)
        assert _finite_pos(s["flops"]) and _finite_pos(s["measured_s"])
        assert s["kernel"] in b["kernels"]
    # the acceptance claim: fitted error beats the uncalibrated roofline
    # on >= 2 of the 3 kernels (recorded, and re-gated by
    # benchmarks/bench_calibration.py --quick in CI)
    assert b["n_improved"] >= 2
    assert set(b["improved"]) == {
        name for name, k in b["kernels"].items()
        if k["fitted_mape"] < k["roofline_mape"]}
    assert all(_finite_pos(v) for v in b["budget_s"].values())
