"""Guards over the committed experiment artifacts: the dry-run table is
complete and the recorded §Perf iterations actually improved their cells."""
import json
from pathlib import Path

import pytest

DRYRUN = Path("experiments/dryrun/results.json")
PERF = Path("experiments/perf_iters.json")
ROOFLINE = Path("experiments/roofline_single_pod.json")


@pytest.mark.skipif(not DRYRUN.exists(), reason="sweep not present")
def test_dryrun_sweep_complete():
    res = json.loads(DRYRUN.read_text())
    ok = [r for r in res.values() if r["status"] == "ok"]
    skip = [r for r in res.values() if r["status"] == "skip"]
    err = [r for r in res.values() if r["status"] == "error"]
    assert len(err) == 0, err
    assert len(ok) == 66   # 33 runnable cells x 2 meshes
    assert len(skip) == 14  # 7 full-attention long_500k x 2 meshes
    # every ok cell has the full record
    for r in ok:
        assert r["memory"]["temp_bytes"] >= 0
        assert r["hlo"]["flops"] > 0
        assert r["hlo"]["bytes"] > 0


@pytest.mark.skipif(not ROOFLINE.exists(), reason="table not present")
def test_roofline_table_covers_40_cells():
    table = json.loads(ROOFLINE.read_text())
    assert len(table) == 40  # 33 ok + 7 documented skips
    ok = [r for r in table.values() if r["status"] == "ok"]
    assert len(ok) == 33
    for r in ok:
        assert r["bound"] in ("compute", "memory", "collective")
        assert r["compute_s"] > 0 and r["memory_s"] > 0


@pytest.mark.skipif(not PERF.exists(), reason="perf log not present")
def test_hillclimb_confirmed_improvements():
    perf = json.loads(PERF.read_text())

    def mem(key):
        return perf[key]["roofline"]["memory_s"]

    # cell A: windowed attention improved gemma3 train + prefill
    base = mem("gemma3_1b|train_4k||mb1")
    best = mem("gemma3_1b|train_4k|attn_remat_chunk,windowed_attention|mb1")
    assert best < 0.6 * base
    # cell B: Megatron-SP improved internvl2
    base = mem("internvl2_26b|train_4k||mb1")
    best = mem("internvl2_26b|train_4k|attn_remat_chunk,"
               "seq_sharded_residual|mb1")
    assert best < 0.6 * base
    # cell C: the refutations are recorded (chunked made it worse)
    base = mem("falcon_mamba_7b|train_4k||mb1")
    worse = mem("falcon_mamba_7b|train_4k|ssm_impl=chunked|mb1")
    assert worse > base
