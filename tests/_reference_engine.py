"""Frozen pre-refactor executor — the PR-base event loop, kept verbatim.

This is the quadratic pure-Python loop that ``repro.sim.engine.run`` shipped
with before the O(E log E) rewrite (per-wave ``ready.sort`` + list rebuild,
``contention_factor`` scanning every historical transfer window, per-op
closure work inside the loop).  It is retained under ``tests/`` as the
ground truth for the equivalence suite: the heap-based engine and the
linear-chain fast path must produce bit-identical Timeline / Breakdown /
Roofline / energy on every program.

Interface models, ``EngineConfig`` and ``EngineResult`` are imported from
the live engine so the *scheduling* semantics are what is frozen here, not
the hardware constants.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.timeline import Timeline
from repro.sim import report
from repro.sim.engine import INTERFACES, EngineConfig, EngineResult
from repro.sim.ir import CostedOp, Program


def run_reference(program: Program, config: EngineConfig = EngineConfig(),
                  *, model_flops: float = 0.0,
                  host_s: Optional[float] = None) -> EngineResult:
    """The pre-refactor ``engine.run`` loop, byte-for-byte."""
    if config.interface not in INTERFACES:
        raise ValueError(f"unknown interface {config.interface!r}; "
                         f"one of {sorted(INTERFACES)}")
    iface = INTERFACES[config.interface]
    tl = Timeline()
    n = max(config.n_workers, 1)
    avail = [0.0] * n
    affinity_worker: Dict[str, int] = {}
    done: Dict[str, float] = {}
    host_free = 0.0
    ici_free = 0.0
    transfers: List[Tuple[float, float]] = []   # active (start, end) windows
    transfer_energy = 0.0
    iface_time_total = [0.0]    # full interface seconds charged this run

    # dependency bookkeeping
    ops = {op.name: op for op in program.ops}
    n_waiting = {op.name: sum(1 for d in op.deps if d in ops)
                 for op in program.ops}
    consumers: Dict[str, List[str]] = {}
    for op in program.ops:
        for d in op.deps:
            if d in ops:
                consumers.setdefault(d, []).append(op.name)
    ready = [op.name for op in program.ops if n_waiting[op.name] == 0]
    if not ready and program.ops:
        raise ValueError("dependency cycle in program")
    scheduled = 0

    def op_compute_s(op: CostedOp) -> float:
        if op.duration_s is not None:
            return op.duration_s
        return op.flops / config.peak_flops

    def op_transfer_base(op: CostedOp) -> Tuple[float, float, float]:
        if op.transfer_s is not None:
            return op.transfer_s, op.transfer_s, config.energy.hbm(
                op.transfer_s * config.hbm_bw)
        if not op.bytes:
            return 0.0, 0.0, 0.0
        t, e = iface(op.bytes, config)
        t /= config.datapath_scale
        exposed = (max(t - op.dot_flops / config.peak_flops, 0.0)
                   if config.overlap else t)
        return t, exposed, e

    def contention_factor(start: float) -> float:
        if config.hbm_ports <= 0:
            return 1.0
        live = 1 + sum(1 for (s, e) in transfers if s <= start < e)
        return max(1.0, live / config.hbm_ports)

    while ready:
        # LPT among currently-ready ops (the legacy scheduler heuristic)
        ready.sort(key=lambda nm: -op_compute_s(ops[nm]))
        batch, ready = ready, []
        for nm in batch:
            op = ops[nm]
            if op.affinity is not None and op.affinity in affinity_worker:
                w = affinity_worker[op.affinity]
            else:
                w = min(range(n), key=lambda i: avail[i])
                if op.affinity is not None:
                    affinity_worker[op.affinity] = w
            dep_ready = max((done[d] for d in op.deps if d in done),
                            default=0.0)
            t = max(avail[w], dep_ready)
            # serial host dispatch (framework time) gates the launch
            host_cost = (config.host_dispatch_s
                         + (op.bytes / config.host_bw / config.host_threads
                            if config.host_bw else 0.0))
            if host_cost > 0.0:
                h0 = max(host_free, dep_ready)
                tl.add("host", f"{op.name}:dispatch", h0, host_cost, "host",
                       phase=op.phase)
                host_free = h0 + host_cost
                t = max(t, host_free)
            # staged input transfer, with HBM-port contention
            full, xfer, xe = op_transfer_base(op)
            transfer_energy += xe
            if xfer > 0.0:
                factor = contention_factor(t)
                xfer *= factor
                tl.add(f"acc{w}", f"{op.name}:xfer", t, xfer, "transfer",
                       phase=op.phase)
                transfers.append((t, t + xfer))
                iface_time_total[0] += full * factor
                t += xfer
            else:
                iface_time_total[0] += full
            comp = op_compute_s(op)
            tl.add(f"acc{w}", op.name, t, comp, "compute", phase=op.phase)
            t += comp
            avail[w] = t
            if op.collective_bytes > 0.0:
                c0 = max(ici_free, t)
                cdur = op.collective_bytes / config.ici_bw
                tl.add("ici", f"{op.name}:coll", c0, cdur, "collective",
                       phase=op.phase)
                ici_free = c0 + cdur
                t = c0 + cdur
            done[nm] = t
            scheduled += 1
            for cn in consumers.get(nm, ()):
                n_waiting[cn] -= 1
                if n_waiting[cn] == 0:
                    ready.append(cn)
    if scheduled != len(program.ops):
        raise ValueError("dependency cycle in program")

    host_floor = config.host_floor_s if host_s is None else host_s
    makespan = tl.makespan
    totals = program.totals()
    bd = report.breakdown_from_events(tl.events, host_floor_s=host_floor)
    if config.overlap:
        bd.transfer_s = max(
            iface_time_total[0] - totals["dot_flops"] / config.peak_flops,
            0.0)
    rl = report.roofline_from_totals(
        totals, host_s=host_floor, n_chips=config.n_chips,
        model_flops=model_flops, peak_flops=config.peak_flops,
        hbm_bw=config.hbm_bw, ici_bw=config.ici_bw)
    e_comp = config.energy.compute(totals["flops"])
    e_ici = config.energy.ici(totals["collective_bytes"])
    e_static = config.energy.static(makespan + host_floor, 1)
    energy = {
        "compute_j": e_comp, "hbm_j": transfer_energy, "ici_j": e_ici,
        "static_j": e_static,
        "total_j": e_comp + transfer_energy + e_ici + e_static,
        "total_j_all_chips": (e_comp + transfer_energy + e_ici + e_static)
        * config.n_chips,
    }
    return EngineResult(timeline=tl, program=program, config=config,
                        breakdown=bd, roofline=rl, energy=energy,
                        makespan=makespan)
