"""Checkpointing: atomic roundtrip, async manager, retention, elastic
restore, crash-recovery semantics."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_checkpoint, save_checkpoint


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"layers": {"w": jnp.asarray(r.standard_normal((4, 8)),
                                        jnp.float32),
                       "b": jnp.asarray(r.standard_normal(8), jnp.float32)},
            "step_scale": jnp.asarray(2.0)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t, extra={"lr": 0.1})
    out = load_checkpoint(str(tmp_path), template=t)
    assert out["step"] == 7
    assert out["extra"]["lr"] == 0.1
    np.testing.assert_array_equal(np.asarray(out["tree"]["layers"]["w"]),
                                  np.asarray(t["layers"]["w"]))


def test_latest_selected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(1))
    save_checkpoint(str(tmp_path), 5, _tree(5))
    out = load_checkpoint(str(tmp_path), template=_tree())
    assert out["step"] == 5


def test_async_manager_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]
    assert mgr.latest_step() == 4


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree(1))
    # simulate a crashed save: stale tmp dir with garbage
    tmp = tmp_path / ".tmp_step_0000000002"
    tmp.mkdir()
    (tmp / "meta.json").write_text("{corrupt")
    out = load_checkpoint(str(tmp_path), template=_tree())
    assert out["step"] == 1  # tmp dirs are invisible to restore
    # and a retried save of step 2 succeeds over the stale tmp
    save_checkpoint(str(tmp_path), 2, _tree(2))
    assert load_checkpoint(str(tmp_path), template=_tree())["step"] == 2


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved unsharded restores onto any mesh (here: 1 device
    with explicit sharding objects) — the elastic-scaling path."""
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), t)
    out = load_checkpoint(str(tmp_path), template=t, shardings=sh, mesh=mesh)
    leaf = out["tree"]["layers"]["w"]
    assert leaf.sharding == NamedSharding(mesh, P())
