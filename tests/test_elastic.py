"""Elastic scaling: checkpoint saved under one mesh restores onto a
different mesh (node-loss / re-provisioning path).  Runs in subprocesses so
device-count flags stay isolated."""
import json
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int, timeout: int = 300):
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_checkpoint_roundtrips_across_meshes(tmp_path):
    save_code = f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import save_checkpoint
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        w = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
        save_checkpoint("{tmp_path}", 5, {{"w": w}})
        print("SAVED")
    """
    restore_code = f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import load_checkpoint
        # DIFFERENT topology: 8-way data-parallel only (elastic re-mesh)
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        t = {{"w": jnp.zeros((8, 8), jnp.float32)}}
        sh = {{"w": NamedSharding(mesh, P("data", None))}}
        out = load_checkpoint("{tmp_path}", template=t, shardings=sh)
        w = out["tree"]["w"]
        assert out["step"] == 5
        expect = np.arange(64, dtype=np.float32).reshape(8, 8)
        np.testing.assert_array_equal(np.asarray(w), expect)
        assert w.sharding.spec == P("data", None)
        print("RESTORED")
    """
    assert "SAVED" in _run(save_code, devices=4)
    assert "RESTORED" in _run(restore_code, devices=8)
