#!/usr/bin/env bash
# CI gate: tier-1 tests + a smoke benchmark through the unified engine,
# so regressions in repro/sim surface automatically.
#
#   tools/ci.sh            # full tier-1 (excluding slow) + smoke benches
#   tools/ci.sh --fast     # engine/scheduler/dist tests only + one bench
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# coverage floor over the simulation core: new repro.sim modules cannot
# land untested.  Gated on pytest-cov being importable (the container may
# not ship it; the floor is enforced wherever it is).
COV_ARGS=""
if python -c "import pytest_cov" >/dev/null 2>&1; then
  COV_ARGS="--cov=repro.sim --cov-fail-under=88"
else
  echo "ci: pytest-cov unavailable; coverage floor (repro.sim >= 88%) skipped"
fi

if [[ "${1:-}" == "--fast" ]]; then
  python -m pytest -q -m "not slow" \
    tests/test_sim_engine.py tests/test_scheduler.py tests/test_dist.py \
    tests/test_sharding.py
else
  # shellcheck disable=SC2086  # COV_ARGS is deliberately word-split
  python -m pytest -q -m "not slow" $COV_ARGS
fi

# smoke the engine-driven case studies (multiacc exercises from_graph +
# worker sweep + port contention; interfaces exercises dma vs acp;
# serving exercises the trace-driven batching layer end to end)
python -m benchmarks.run --only multiacc
python -m benchmarks.run --only interfaces
python -m benchmarks.run --only serving

# docs gate: every fenced ```python block in the README and the guide must
# execute — documentation cannot rot silently
python tools/run_doc_snippets.py README.md docs/GUIDE.md

# perf smoke: engine/sweep timings must stay within 2x of the budgets
# recorded in BENCH_engine.json (fails the build on >2x regression)
python -m benchmarks.bench_engine_perf --quick

# SoC smoke: the heterogeneous camera-SoC sweep within 2x of its
# BENCH_soc.json budget + the homogeneous-topology == flat-config
# bit-identity probe
python -m benchmarks.bench_soc --quick

# DSE smoke: the vectorized analytic grid within 2x of its BENCH_dse.json
# budget + the correctness gates (chain relaxation_err == 0, DAG bracket
# holds, optimize within 2% of the port-study grid best, recorded
# batched-vs-process speedup >= 50x)
python -m benchmarks.bench_dse --quick

# training smoke: the pipeline-parallel sweep within 2x of its
# BENCH_training.json budget + the schedule probes (1F1B never loses to
# GPipe on homogeneous uncontended stages; ideal bubble == (p-1)/(m+p-1))
python -m benchmarks.bench_training --quick

# fleet smoke: the memoized 100k-request replay within 2x of its
# BENCH_fleet.json budget, the replay rate at >= half the recorded 1M
# headline, and the bit-identity (replay == full co-simulation) +
# router-conservation probes (recorded speedup floor >= 10x)
python -m benchmarks.bench_fleet --quick

# cluster smoke: the DP x TP x PP fabric grid within 2x of its
# BENCH_cluster.json budget + the collective probes (engine == closed-form
# ring/tree/hierarchical bounds at rel 1e-12, hierarchical <= ring on the
# multi-tier fabric, single-tier fabric bit-identical to the flat config)
python -m benchmarks.bench_cluster --quick

# calibration smoke: re-measure the quick Pallas-kernel grid within 2x of
# its BENCH_calibration.json budget + the measured-vs-modeled gates
# (fitted model beats the uncalibrated roofline on >= 2 of 3 kernels,
# matmul fitted MAPE under its ceiling, measured table round-trips
# bit-exactly)
python -m benchmarks.bench_calibration --quick

echo "CI OK"
