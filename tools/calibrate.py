#!/usr/bin/env python
"""Calibrate the cost backends against the real Pallas kernels.

Times ``repro/kernels/`` (``nvdla_matmul``, ``flash_attention``,
``mamba_scan``) over a shape grid with best-of-k, fits per-kernel
(flops, bytes, overhead) cost parameters by least squares, and prints —
or writes — the calibration report.  The CI-gated artifact writer is
``benchmarks/bench_calibration.py``; this is the standalone harness for
poking at grids and repeats:

    PYTHONPATH=src python tools/calibrate.py --grid quick
    PYTHONPATH=src python tools/calibrate.py --repeat 5 --out cal.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.kernels import calibrate  # noqa: E402


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", choices=("full", "quick"), default="full")
    ap.add_argument("--repeat", type=int, default=3,
                    help="best-of-k repeats per shape (default 3)")
    ap.add_argument("--kernels", nargs="+", default=list(calibrate.KERNELS),
                    choices=list(calibrate.KERNELS),
                    help="subset of kernels to measure")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write the report JSON here instead of stdout")
    args = ap.parse_args()

    records, meta = calibrate.measure(grid=args.grid, repeat=args.repeat,
                                      kernels=args.kernels)
    report = calibrate.build_report(records, meta)
    text = json.dumps(report, indent=2, default=float) + "\n"
    if args.out:
        args.out.write_text(text)
        print(f"wrote {args.out}")
    else:
        sys.stdout.write(text)
    for name in sorted(report["kernels"]):
        f = report["kernels"][name]
        print(f"{name}: roofline_mape={f['roofline_mape']:.3g} -> "
              f"fitted_mape={f['fitted_mape']:.3g}", file=sys.stderr)


if __name__ == "__main__":
    main()
