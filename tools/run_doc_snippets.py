#!/usr/bin/env python
"""Docs gate: extract and execute the fenced Python blocks in markdown.

Every block fenced as ```python in the given files is executed, in file
order, with ONE shared namespace per file — so a tutorial can build on its
earlier blocks the way a reader follows it.  A block fenced as
```python no-run is displayed-only (use sparingly: for output samples or
deliberately failing snippets).  Any exception fails the run with the
offending file, block, and source line — documentation cannot rot
silently once ``tools/ci.sh`` calls this.

  PYTHONPATH=src python tools/run_doc_snippets.py README.md docs/GUIDE.md

Exit status: 0 if every block ran, 1 otherwise (or if a file has no
runnable blocks at all, which usually means a fence typo).
"""
from __future__ import annotations

import pathlib
import sys
import traceback
from typing import List, Tuple

ROOT = pathlib.Path(__file__).resolve().parents[1]


def extract_blocks(text: str) -> List[Tuple[int, str, str]]:
    """(start line, info string, code) for every fenced code block."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped.startswith("```") and stripped != "```":
            info = stripped[3:].strip()
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            blocks.append((start + 1, info, "\n".join(body) + "\n"))
        i += 1
    return blocks


def run_file(path: pathlib.Path) -> Tuple[int, int]:
    """Execute a file's python blocks in one shared namespace.
    Returns (blocks run, failures)."""
    blocks = extract_blocks(path.read_text())
    py = [(ln, code) for ln, info, code in blocks
          if (info == "python" or info.startswith("python "))
          and "no-run" not in info]
    ns: dict = {"__name__": f"doc_snippets:{path.name}"}
    ran = failures = 0
    for idx, (ln, code) in enumerate(py):
        try:
            exec(compile(code, f"{path}:block{idx}(line {ln})", "exec"), ns)
            ran += 1
        except Exception:
            failures += 1
            print(f"FAIL {path} block {idx} (line {ln}):", file=sys.stderr)
            print("\n".join(f"    {l}" for l in code.splitlines()),
                  file=sys.stderr)
            traceback.print_exc()
    return ran, failures


def main(argv: List[str]) -> int:
    if not argv:
        print(__doc__)
        return 1
    total = failures = 0
    for arg in argv:
        path = pathlib.Path(arg)
        if not path.is_absolute():
            path = ROOT / arg
        if not path.exists():
            print(f"no such file: {path}", file=sys.stderr)
            return 1
        ran, bad = run_file(path)
        total += ran
        failures += bad
        status = "OK" if not bad else f"{bad} FAILED"
        print(f"{path.relative_to(ROOT)}: {ran} python block(s) {status}")
        if ran == 0 and not bad:
            print(f"  no runnable ```python blocks found in {path.name} — "
                  "fence typo?", file=sys.stderr)
            failures += 1
    if failures:
        print(f"docs gate: {failures} failing block(s)", file=sys.stderr)
        return 1
    print(f"docs gate: {total} block(s) OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
